//! Per-run metric collection and summaries — one struct per experiment run,
//! producing exactly the quantities the paper's figures report.

use crate::config::TelemetryConfig;
use crate::stats::{Dist, LoadImbalance, OnlineStats, TimeSeries};
use crate::util::hashing::mix64;
use crate::util::json::{obj, Json};

/// A finite number as JSON, `null` otherwise — NaN (empty-stream
/// percentiles/means) and ±∞ (empty-stream min/max) must never leak
/// into exported JSON, where they would not even parse.
fn num_or_null(x: f64) -> Json {
    if x.is_finite() {
        x.into()
    } else {
        Json::Null
    }
}

/// One timed phase of a sampled request's lifecycle.
///
/// Times are in the run's native clock — virtual seconds for the
/// simulator, wall seconds since server start for real-time runs.
/// Instantaneous events (arrival, decide, complete) have
/// `start_s == end_s`.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceSpan {
    /// Request id: the per-shard dense request counter.
    pub request: u64,
    /// Function the request invoked.
    pub function: usize,
    /// Shard that processed the request (0 for serial runs).
    pub shard: usize,
    /// Phase name: one of `arrival`, `decide`, `pending`, `bind`,
    /// `cold_init`, `service`, `complete`.
    pub phase: &'static str,
    /// Span start in seconds.
    pub start_s: f64,
    /// Span end in seconds (equal to `start_s` for instant events).
    pub end_s: f64,
    /// Worker involved, when one is known for the phase.
    pub worker: Option<usize>,
    /// Phase-specific detail: the decision outcome for `decide`
    /// (`assign`/`enqueue`/`reject`), the bind kind for `bind`
    /// (`pull`/`idle`/`deadline`/`flush`/`steal`), cold/warm for
    /// `service` and `complete`, empty otherwise.
    pub detail: String,
}

/// Request-lifecycle trace with deterministic sampling.
///
/// A request with id `rid` is traced iff `mix64(rid) % sample == 0` —
/// a pure function of the request id, so the same (config, seed,
/// shards) triple always traces the same requests and the trace output
/// is bit-reproducible. Sampling never consumes scheduler or service
/// RNG draws and never changes event order, so enabling tracing leaves
/// every other metric bit-identical.
#[derive(Clone, Debug)]
pub struct TraceLog {
    sample: u64,
    max: usize,
    shard: usize,
    spans: Vec<TraceSpan>,
    truncated: u64,
}

impl TraceLog {
    /// A trace collecting every `sample`-th request (by hash gate), at
    /// most `max` spans. `sample == 0` disables tracing entirely.
    pub fn new(sample: u64, max: usize) -> Self {
        Self { sample, max, shard: 0, spans: Vec::new(), truncated: 0 }
    }

    /// A disabled trace (the default for plain runs).
    pub fn off() -> Self {
        Self::new(0, 0)
    }

    /// Whether tracing is enabled at all.
    pub fn enabled(&self) -> bool {
        self.sample != 0
    }

    /// Tag subsequently recorded spans with `shard` (sharded engines
    /// set this from their shard index; serial runs stay at 0).
    pub fn set_shard(&mut self, shard: usize) {
        self.shard = shard;
    }

    /// Whether request `rid` is in the deterministic sample.
    pub fn sampled(&self, rid: u64) -> bool {
        self.sample != 0 && mix64(rid) % self.sample == 0
    }

    /// Record one span for request `rid` if it is sampled and the span
    /// cap has not been reached.
    #[allow(clippy::too_many_arguments)]
    pub fn record(
        &mut self,
        rid: u64,
        function: usize,
        phase: &'static str,
        start_s: f64,
        end_s: f64,
        worker: Option<usize>,
        detail: &str,
    ) {
        if !self.sampled(rid) {
            return;
        }
        if self.spans.len() >= self.max {
            self.truncated += 1;
            return;
        }
        self.spans.push(TraceSpan {
            request: rid,
            function,
            shard: self.shard,
            phase,
            start_s,
            end_s,
            worker,
            detail: detail.to_string(),
        });
    }

    /// The recorded spans, in recording order (shard order after merge).
    pub fn spans(&self) -> &[TraceSpan] {
        &self.spans
    }

    /// Number of recorded spans.
    pub fn len(&self) -> usize {
        self.spans.len()
    }

    /// Whether no spans were recorded.
    pub fn is_empty(&self) -> bool {
        self.spans.is_empty()
    }

    /// Spans dropped after the cap was hit (sampled but not stored).
    pub fn truncated(&self) -> u64 {
        self.truncated
    }

    /// Append another shard's spans (shard-merge reduction; spans stay
    /// grouped by shard, ordered by the merge call order).
    pub fn merge_append(&mut self, other: &TraceLog) {
        self.spans.extend(other.spans.iter().cloned());
        self.truncated += other.truncated;
        if other.sample != 0 && self.sample == 0 {
            self.sample = other.sample;
            self.max = other.max;
        }
    }
}

/// Wall-clock accounting of where the engine's hot loop spends time.
///
/// Timers use `std::time::Instant` and only ever write into this
/// struct — they never feed back into simulation state, so profiling
/// cannot perturb virtual time or event order. All fields are real
/// (wall) seconds, even for virtual-time runs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct PhaseProfile {
    /// Whether profiling was on for the run (gates the summary block).
    pub enabled: bool,
    /// Seconds popping events off the calendar/heap.
    pub pop_s: f64,
    /// Seconds dispatching events (scheduler decide + handlers).
    pub decide_s: f64,
    /// Seconds blocked at epoch barriers (sharded runs only).
    pub barrier_s: f64,
    /// Seconds extracting/ingesting cross-shard handoffs.
    pub handoff_s: f64,
    /// Seconds in autoscale ticks.
    pub autoscale_s: f64,
    /// Total wall seconds in the event loop (the `*_frac` denominator).
    pub wall_s: f64,
}

impl PhaseProfile {
    /// An empty profile; `enabled` gates both timing and reporting.
    pub fn new(enabled: bool) -> Self {
        Self { enabled, ..Default::default() }
    }

    /// Sum another shard's phase times into this one (phase fractions
    /// then describe the aggregate across shard threads).
    pub fn merge_add(&mut self, other: &PhaseProfile) {
        self.enabled |= other.enabled;
        self.pop_s += other.pop_s;
        self.decide_s += other.decide_s;
        self.barrier_s += other.barrier_s;
        self.handoff_s += other.handoff_s;
        self.autoscale_s += other.autoscale_s;
        self.wall_s += other.wall_s;
    }

    /// `x` as a fraction of total loop wall time (0 when nothing ran).
    pub fn frac(&self, x: f64) -> f64 {
        if self.wall_s > 0.0 {
            x / self.wall_s
        } else {
            0.0
        }
    }

    /// The profile as JSON: absolute seconds, fractions of loop wall
    /// time, and the process peak RSS (null off Linux).
    pub fn json(&self) -> Json {
        obj(vec![
            ("pop_s", self.pop_s.into()),
            ("decide_s", self.decide_s.into()),
            ("barrier_s", self.barrier_s.into()),
            ("handoff_s", self.handoff_s.into()),
            ("autoscale_s", self.autoscale_s.into()),
            ("wall_s", self.wall_s.into()),
            ("pop_frac", self.frac(self.pop_s).into()),
            ("decide_frac", self.frac(self.decide_s).into()),
            ("barrier_frac", self.frac(self.barrier_s).into()),
            ("handoff_frac", self.frac(self.handoff_s).into()),
            ("autoscale_frac", self.frac(self.autoscale_s).into()),
            (
                "peak_rss_mb",
                match crate::util::sysinfo::peak_rss_mb() {
                    Some(mb) => mb.into(),
                    None => Json::Null,
                },
            ),
        ])
    }
}

/// Collected during a run (sim or real-time).
#[derive(Clone, Debug)]
pub struct RunMetrics {
    /// Scheduler name the run used.
    pub scheduler: String,
    /// Virtual users the run was configured with.
    pub vus: usize,
    /// Response latencies in ms (arrival -> response), all completed
    /// requests — exact samples by default, a mergeable quantile sketch
    /// under `[telemetry] sketch = true`.
    pub latency_ms: Dist,
    /// Response latencies split by cold/warm (Table I reproduction).
    pub latency_cold_ms: Dist,
    /// Warm-start response latencies in ms.
    pub latency_warm_ms: Dist,
    /// Requests whose execution required creating a sandbox.
    pub cold_starts: u64,
    /// Requests served by an existing warm sandbox.
    pub warm_starts: u64,
    /// Requests assigned per worker per second (Figs 14/15).
    pub imbalance: LoadImbalance,
    /// Completions per second (Figs 16/17).
    pub throughput: TimeSeries,
    /// Cold starts per second (windowed cold-rate analysis, e.g. around
    /// auto-scaling events).
    pub cold_series: TimeSeries,
    /// Worker-queue delay (scheduling quality diagnostic).
    pub queue_delay_ms: OnlineStats,
    /// Requests refused by admission control (`Decision::Reject`). Counted
    /// separately from `issued`/`completed` so rejects never silently
    /// vanish from the latency percentiles.
    pub rejected: u64,
    /// Admission rejects split by function (indexed by `FunctionId`,
    /// grown on demand): per-function caps isolate rejects to the
    /// function that overflows, and this is where that shows. Sums to
    /// `rejected`.
    pub rejected_by_fn: Vec<u64>,
    /// Requests that were parked in the router's pending queue
    /// (`Decision::Enqueue`, pull dispatch).
    pub enqueued: u64,
    /// Parked requests handed off across shards at epoch barriers
    /// (`ShardMsg::Handoff`), counted at the receiving shard.
    pub stolen: u64,
    /// Pending-queue wait per parked request, ms (arrival → worker bind).
    pub pending_wait_ms: Dist,
    /// Pending-queue waits split by function (indexed by `FunctionId`,
    /// grown on demand) — the fairness diagnostic: a starved function
    /// shows up as a heavy per-function tail long before it moves the
    /// pooled percentiles.
    pub pending_wait_by_fn_ms: Vec<Dist>,
    /// Pending-queue depth timeline, sampled at the keep-alive sweep tick
    /// (pull dispatch only; empty otherwise).
    pub pending_timeline: Vec<(f64, usize)>,
    /// High-water mark of the pending queue. Sharded runs sum the
    /// per-shard peaks (like `peak_event_queue`): an upper-bound proxy
    /// for the global backlog, not an exact simultaneous maximum.
    pub peak_pending: usize,
    /// Autoscale timeline: (time, active workers after the event). The
    /// first entry is the initial worker count at t=0; a static run has
    /// exactly one entry.
    pub scaling_timeline: Vec<(f64, usize)>,
    /// Integral of active workers over the run (cost proxy): one worker
    /// kept for one second = one worker-second.
    pub worker_seconds: f64,
    /// Speculative sandboxes initialized (predictive pre-warming).
    pub prewarm_spawned: u64,
    /// Warm starts served by a pre-warmed (never-before-used) sandbox.
    pub prewarm_hits: u64,
    /// Simulation events processed (the perf sweep's events/s numerator;
    /// 0 for real-time runs).
    pub events_processed: u64,
    /// High-water mark of the pending-event queue (perf diagnostics).
    pub peak_event_queue: usize,
    /// Configured run duration in (virtual) seconds.
    pub duration_s: f64,
    /// Requests that completed.
    pub completed: u64,
    /// Requests that were issued (routed). Under fault injection a
    /// retried request is re-issued on every re-bind, so `issued` counts
    /// binds (assignment-rate semantics), not distinct requests — use
    /// `arrivals` for the conservation identity.
    pub issued: u64,
    /// Whether fault injection was active for the run (gates the `faults`
    /// summary block; OR-ed by [`RunMetrics::merge`]).
    pub faults_enabled: bool,
    /// Distinct requests that arrived (admitted or rejected at issue
    /// time; maintained in every run). The conservation identity is
    /// `arrivals == completed + rejected + failed`, plus `stolen` when
    /// shards are merged (a cross-shard handoff counts the request at
    /// both ends and the donor's copy resolves as the donation).
    pub arrivals: u64,
    /// Injected worker crashes that fired.
    pub worker_crashes: u64,
    /// Crashed workers that rejoined the cluster.
    pub worker_recoveries: u64,
    /// Requests whose retry budget was exhausted — terminally failed,
    /// never silently dropped.
    pub failed: u64,
    /// Executions lost to a fault and re-enqueued with backoff.
    pub retried: u64,
    /// Straggler-held requests duplicated onto the pull path.
    pub hedged: u64,
    /// Selections that landed on a dead worker and were re-routed to a
    /// live one at bind time (late binding's recovery advantage).
    pub re_routed: u64,
    /// Re-routed requests that carried warm sandbox state with them
    /// (warm-state handoff within the keep-alive window).
    pub migrated: u64,
    /// Sandbox cold-init failures injected.
    pub init_failures: u64,
    /// Worker downtime per recovery in ms (crash -> rejoin) — how long
    /// the cluster ran degraded each time a worker died.
    pub recovery_latency_ms: Dist,
    /// Whether the run was core-granular (`sim.cores_per_worker > 1`) or
    /// had a push rebind window armed — gates the `slots` summary block
    /// (OR-ed by [`RunMetrics::merge`]). Default runs emit no slot keys,
    /// keeping their summaries byte-identical to the pre-slot engine.
    pub slots_enabled: bool,
    /// Push-mode rebinds: queued requests re-offered to a better slot
    /// that freed within `dispatch.rebind_window_s` (DESIGN.md §11).
    pub rebound: u64,
    /// Arrival → execution-start wait of short-class functions, ms
    /// (`dispatch::is_short_class`). The head-of-line-blocking money
    /// metric: at worker granularity short functions queue behind long
    /// ones; core granularity should collapse this tail. Recorded in
    /// every run (Dist pushes perturb nothing); only *reported* when
    /// `slots_enabled`.
    pub hol_wait_short_ms: Dist,
    /// Arrival → execution-start wait of long-class functions, ms.
    pub hol_wait_long_ms: Dist,
    /// Busy-slot timeline (time, busy core slots across active workers),
    /// sampled at the keep-alive sweep tick in core-granular runs only.
    pub slot_timeline: Vec<(f64, usize)>,
    /// Sampled request-lifecycle spans (disabled unless
    /// `telemetry.trace_sample > 0`).
    pub trace: TraceLog,
    /// Engine phase profile (zeroed unless `telemetry.phase_profile`).
    pub phases: PhaseProfile,
    // Distribution mode memo, so lazily grown per-function tables get
    // the same storage mode as the pooled distributions they merge with.
    sketch: bool,
    sketch_alpha: f64,
}

impl RunMetrics {
    /// An empty collector for one run of `scheduler` over `workers`
    /// workers, `vus` virtual users and `duration_s` seconds.
    pub fn new(scheduler: &str, workers: usize, vus: usize, duration_s: f64) -> Self {
        Self::with_telemetry(scheduler, workers, vus, duration_s, &TelemetryConfig::default())
    }

    /// An empty collector whose storage mode, trace sampling and phase
    /// profiling follow `[telemetry]` config. `RunMetrics::new` is the
    /// all-defaults (exact, untraced, unprofiled) special case.
    pub fn with_telemetry(
        scheduler: &str,
        workers: usize,
        vus: usize,
        duration_s: f64,
        tel: &TelemetryConfig,
    ) -> Self {
        let dist = || Dist::for_mode(tel.sketch, tel.sketch_alpha);
        Self {
            scheduler: scheduler.to_string(),
            vus,
            latency_ms: dist(),
            latency_cold_ms: dist(),
            latency_warm_ms: dist(),
            cold_starts: 0,
            warm_starts: 0,
            imbalance: LoadImbalance::new(workers, 1.0),
            throughput: TimeSeries::new(1.0),
            cold_series: TimeSeries::new(1.0),
            queue_delay_ms: OnlineStats::new(),
            rejected: 0,
            rejected_by_fn: Vec::new(),
            enqueued: 0,
            stolen: 0,
            pending_wait_ms: dist(),
            pending_wait_by_fn_ms: Vec::new(),
            pending_timeline: Vec::new(),
            peak_pending: 0,
            scaling_timeline: Vec::new(),
            worker_seconds: 0.0,
            prewarm_spawned: 0,
            prewarm_hits: 0,
            events_processed: 0,
            peak_event_queue: 0,
            duration_s,
            completed: 0,
            issued: 0,
            faults_enabled: false,
            arrivals: 0,
            worker_crashes: 0,
            worker_recoveries: 0,
            failed: 0,
            retried: 0,
            hedged: 0,
            re_routed: 0,
            migrated: 0,
            init_failures: 0,
            recovery_latency_ms: dist(),
            slots_enabled: false,
            rebound: 0,
            hol_wait_short_ms: dist(),
            hol_wait_long_ms: dist(),
            slot_timeline: Vec::new(),
            trace: TraceLog::new(tel.trace_sample, tel.trace_max),
            phases: PhaseProfile::new(tel.phase_profile),
            sketch: tel.sketch,
            sketch_alpha: tel.sketch_alpha,
        }
    }

    /// Record the active-worker count changing to `active` at time `t`
    /// (also called once at t=0 with the initial count).
    pub fn record_scale(&mut self, t: f64, active: usize) {
        if let Some(&(t0, a0)) = self.scaling_timeline.last() {
            self.worker_seconds += (t - t0).max(0.0) * a0 as f64;
        }
        self.scaling_timeline.push((t, active));
    }

    /// Close the worker-seconds integral at the end of the run.
    pub fn finalize_scaling(&mut self, end_t: f64) {
        if let Some(&(t0, a0)) = self.scaling_timeline.last() {
            if end_t > t0 {
                self.worker_seconds += (end_t - t0) * a0 as f64;
                self.scaling_timeline.push((end_t, a0));
            }
        }
    }

    /// One request was routed to `worker` at time `t`.
    pub fn record_assignment(&mut self, worker: usize, t: f64) {
        self.imbalance.record_assignment(worker, t);
        self.issued += 1;
    }

    /// One request for function `f` was refused by admission control.
    pub fn record_reject(&mut self, f: usize) {
        self.rejected += 1;
        if f >= self.rejected_by_fn.len() {
            self.rejected_by_fn.resize(f + 1, 0);
        }
        self.rejected_by_fn[f] += 1;
    }

    /// Admission rejects recorded for function `f`.
    pub fn reject_count_fn(&self, f: usize) -> u64 {
        self.rejected_by_fn.get(f).copied().unwrap_or(0)
    }

    /// One request was parked in the pending queue, which now holds
    /// `depth` requests.
    pub fn record_enqueue(&mut self, depth: usize) {
        self.enqueued += 1;
        if depth > self.peak_pending {
            self.peak_pending = depth;
        }
    }

    /// A parked request for function `f` was bound to a worker after
    /// waiting `wait_s`.
    pub fn record_pending_wait(&mut self, f: usize, wait_s: f64) {
        self.pending_wait_ms.push(wait_s * 1000.0);
        if f >= self.pending_wait_by_fn_ms.len() {
            let (sketch, alpha) = (self.sketch, self.sketch_alpha);
            self.pending_wait_by_fn_ms
                .resize_with(f + 1, || Dist::for_mode(sketch, alpha));
        }
        self.pending_wait_by_fn_ms[f].push(wait_s * 1000.0);
    }

    /// p99 pending wait in ms for function `f` (0 when it never parked).
    pub fn pending_wait_p99_fn_ms(&mut self, f: usize) -> f64 {
        match self.pending_wait_by_fn_ms.get_mut(f) {
            Some(s) if !s.is_empty() => s.percentile(99.0),
            _ => 0.0,
        }
    }

    /// Pending-queue depth sample at time `t` (1 Hz in pull mode).
    pub fn record_pending_depth(&mut self, t: f64, depth: usize) {
        self.pending_timeline.push((t, depth));
    }

    /// A request started executing `wait_s` after arrival; attribute the
    /// wait to its runtime class (head-of-line-blocking breakdown).
    pub fn record_hol_wait(&mut self, short: bool, wait_s: f64) {
        if short {
            self.hol_wait_short_ms.push(wait_s * 1000.0);
        } else {
            self.hol_wait_long_ms.push(wait_s * 1000.0);
        }
    }

    /// p99 arrival → start wait in ms for one runtime class (0 when the
    /// class never ran).
    pub fn hol_wait_p99_ms(&mut self, short: bool) -> f64 {
        let d = if short { &mut self.hol_wait_short_ms } else { &mut self.hol_wait_long_ms };
        if d.is_empty() {
            0.0
        } else {
            d.percentile(99.0)
        }
    }

    /// Busy-slot sample at time `t` (core-granular runs, sweep tick).
    pub fn record_slot_depth(&mut self, t: f64, busy: usize) {
        self.slot_timeline.push((t, busy));
    }

    /// One request completed: record its end-to-end latency, cold/warm
    /// outcome and worker-queue delay at completion time `t`.
    pub fn record_response(
        &mut self,
        latency_s: f64,
        cold: bool,
        queue_delay_s: f64,
        t: f64,
    ) {
        let ms = latency_s * 1000.0;
        self.latency_ms.push(ms);
        if cold {
            self.cold_starts += 1;
            self.latency_cold_ms.push(ms);
            self.cold_series.increment(t.min(self.duration_s * 1.999));
        } else {
            self.warm_starts += 1;
            self.latency_warm_ms.push(ms);
        }
        self.queue_delay_ms.push(queue_delay_s * 1000.0);
        self.throughput.increment(t.min(self.duration_s * 1.999));
        self.completed += 1;
    }

    // ---- derived quantities (the paper's reported metrics) --------------

    /// Fraction of requests that experienced a cold start (Fig 13).
    pub fn cold_rate(&self) -> f64 {
        let total = self.cold_starts + self.warm_starts;
        if total == 0 {
            0.0
        } else {
            self.cold_starts as f64 / total as f64
        }
    }

    /// Mean response latency in ms (Fig 11).
    pub fn mean_latency_ms(&mut self) -> f64 {
        self.latency_ms.mean()
    }

    /// Tail latency percentile in ms (Fig 12).
    pub fn latency_percentile_ms(&mut self, p: f64) -> f64 {
        self.latency_ms.percentile(p)
    }

    /// Average CV of per-worker assignment rate (Fig 15).
    pub fn mean_cv(&self) -> f64 {
        self.imbalance.mean_cv()
    }

    /// Completed requests per second over the run (Fig 17).
    pub fn rps(&self) -> f64 {
        self.completed as f64 / self.duration_s
    }

    /// Number of scaling actions that changed the worker count.
    pub fn scale_event_count(&self) -> usize {
        self.scaling_timeline.windows(2).filter(|w| w[0].1 != w[1].1).count()
    }

    /// Fraction of pre-warmed sandboxes that served a warm start before
    /// being evicted (speculation accuracy).
    pub fn prewarm_hit_rate(&self) -> f64 {
        if self.prewarm_spawned == 0 {
            0.0
        } else {
            self.prewarm_hits as f64 / self.prewarm_spawned as f64
        }
    }

    /// Fraction of admission attempts that were refused: rejected over
    /// (issued + rejected). 0 when nothing arrived.
    pub fn reject_rate(&self) -> f64 {
        let total = self.issued + self.rejected;
        if total == 0 {
            0.0
        } else {
            self.rejected as f64 / total as f64
        }
    }

    /// Mean pending-queue wait in ms over parked requests (0 when nothing
    /// was parked — push mode, or a pull run that never enqueued).
    pub fn mean_pending_wait_ms(&self) -> f64 {
        if self.pending_wait_ms.is_empty() {
            0.0
        } else {
            self.pending_wait_ms.mean()
        }
    }

    /// Fold another run's raw measurements into this one — the shard-merge
    /// reduction over disjoint worker sets and request streams sharing one
    /// virtual clock. Samples are unioned (derived percentiles/rates are
    /// then exact over the union), per-worker series are appended in shard
    /// order, the scaling timelines are added as step functions (so
    /// `worker_seconds` stays the integral of the *global* active-worker
    /// count), and counters sum. `scheduler`, `vus` and `duration_s` keep
    /// `self`'s values; `peak_event_queue` and `peak_pending` sum (total
    /// backlog across shard queues is the meaningful high-water proxy —
    /// per-shard peaks need not be simultaneous, so the sum is an upper
    /// bound, not an exact global maximum).
    pub fn merge(&mut self, other: &RunMetrics) {
        self.latency_ms.merge_from(&other.latency_ms);
        self.latency_cold_ms.merge_from(&other.latency_cold_ms);
        self.latency_warm_ms.merge_from(&other.latency_warm_ms);
        self.cold_starts += other.cold_starts;
        self.warm_starts += other.warm_starts;
        self.imbalance.merge_append(&other.imbalance);
        self.throughput.merge_add(&other.throughput);
        self.cold_series.merge_add(&other.cold_series);
        self.queue_delay_ms.merge(&other.queue_delay_ms);
        self.rejected += other.rejected;
        if other.rejected_by_fn.len() > self.rejected_by_fn.len() {
            self.rejected_by_fn.resize(other.rejected_by_fn.len(), 0);
        }
        for (acc, &c) in self.rejected_by_fn.iter_mut().zip(&other.rejected_by_fn) {
            *acc += c;
        }
        self.enqueued += other.enqueued;
        self.stolen += other.stolen;
        self.pending_wait_ms.merge_from(&other.pending_wait_ms);
        if other.pending_wait_by_fn_ms.len() > self.pending_wait_by_fn_ms.len() {
            let (sketch, alpha) = (self.sketch, self.sketch_alpha);
            self.pending_wait_by_fn_ms
                .resize_with(other.pending_wait_by_fn_ms.len(), || Dist::for_mode(sketch, alpha));
        }
        for (acc, s) in self.pending_wait_by_fn_ms.iter_mut().zip(&other.pending_wait_by_fn_ms) {
            acc.merge_from(s);
        }
        self.pending_timeline = merge_timelines(&self.pending_timeline, &other.pending_timeline);
        self.peak_pending += other.peak_pending;
        self.scaling_timeline = merge_timelines(&self.scaling_timeline, &other.scaling_timeline);
        self.worker_seconds += other.worker_seconds;
        self.prewarm_spawned += other.prewarm_spawned;
        self.prewarm_hits += other.prewarm_hits;
        self.events_processed += other.events_processed;
        self.peak_event_queue += other.peak_event_queue;
        self.completed += other.completed;
        self.issued += other.issued;
        self.faults_enabled |= other.faults_enabled;
        self.arrivals += other.arrivals;
        self.worker_crashes += other.worker_crashes;
        self.worker_recoveries += other.worker_recoveries;
        self.failed += other.failed;
        self.retried += other.retried;
        self.hedged += other.hedged;
        self.re_routed += other.re_routed;
        self.migrated += other.migrated;
        self.init_failures += other.init_failures;
        self.recovery_latency_ms.merge_from(&other.recovery_latency_ms);
        self.slots_enabled |= other.slots_enabled;
        self.rebound += other.rebound;
        self.hol_wait_short_ms.merge_from(&other.hol_wait_short_ms);
        self.hol_wait_long_ms.merge_from(&other.hol_wait_long_ms);
        self.slot_timeline = merge_timelines(&self.slot_timeline, &other.slot_timeline);
        self.trace.merge_append(&other.trace);
        self.phases.merge_add(&other.phases);
    }

    /// Summary as JSON (dumped by the CLI for external plotting).
    pub fn summary_json(&mut self) -> Json {
        let mean = self.mean_latency_ms();
        let p50 = self.latency_percentile_ms(50.0);
        let p90 = self.latency_percentile_ms(90.0);
        let p95 = self.latency_percentile_ms(95.0);
        let p99 = self.latency_percentile_ms(99.0);
        // Per-function admission/wait breakdowns as sparse [id, value]
        // pairs (functions with nothing to report are omitted, so push
        // runs emit empty arrays).
        let rejects_by_fn: Vec<Json> = self
            .rejected_by_fn
            .iter()
            .enumerate()
            .filter(|&(_, &c)| c > 0)
            .map(|(f, &c)| Json::Arr(vec![(f as u64).into(), c.into()]))
            .collect();
        let mut p99_wait_by_fn: Vec<Json> = Vec::new();
        for f in 0..self.pending_wait_by_fn_ms.len() {
            if !self.pending_wait_by_fn_ms[f].is_empty() {
                let p = self.pending_wait_by_fn_ms[f].percentile(99.0);
                p99_wait_by_fn.push(Json::Arr(vec![(f as u64).into(), p.into()]));
            }
        }
        let mut pairs = vec![
            ("scheduler", self.scheduler.as_str().into()),
            ("vus", self.vus.into()),
            ("completed", self.completed.into()),
            ("issued", self.issued.into()),
            ("mean_latency_ms", num_or_null(mean)),
            ("p50_ms", num_or_null(p50)),
            ("p90_ms", num_or_null(p90)),
            ("p95_ms", num_or_null(p95)),
            ("p99_ms", num_or_null(p99)),
            ("cold_rate", self.cold_rate().into()),
            ("cold_starts", self.cold_starts.into()),
            ("warm_starts", self.warm_starts.into()),
            ("mean_cv", num_or_null(self.mean_cv())),
            ("rps", self.rps().into()),
            ("mean_queue_delay_ms", num_or_null(self.queue_delay_ms.mean())),
            ("worker_seconds", self.worker_seconds.into()),
            ("scale_events", self.scale_event_count().into()),
            ("prewarm_spawned", self.prewarm_spawned.into()),
            ("prewarm_hit_rate", self.prewarm_hit_rate().into()),
            ("rejected", self.rejected.into()),
            ("reject_rate", self.reject_rate().into()),
            ("enqueued", self.enqueued.into()),
            ("stolen", self.stolen.into()),
            ("mean_pending_wait_ms", self.mean_pending_wait_ms().into()),
            ("peak_pending", self.peak_pending.into()),
            ("rejects_by_fn", Json::Arr(rejects_by_fn)),
            ("p99_pending_wait_by_fn_ms", Json::Arr(p99_wait_by_fn)),
        ];
        // Non-default telemetry surfaces extra keys; the default path
        // emits exactly the historical key set so summaries stay
        // byte-identical run-to-run and release-to-release.
        if self.latency_ms.is_sketch() {
            pairs.push(("sketch", true.into()));
        }
        if self.trace.enabled() {
            pairs.push(("trace_spans", (self.trace.len() as u64).into()));
            pairs.push(("trace_truncated", self.trace.truncated().into()));
        }
        if self.phases.enabled {
            pairs.push(("phases", self.phases.json()));
        }
        // Fault-free runs (the default) emit no fault keys at all, so
        // their summaries stay byte-identical to the pre-fault engine.
        if self.faults_enabled {
            let (rec_mean, rec_p99) = if self.recovery_latency_ms.is_empty() {
                (f64::NAN, f64::NAN)
            } else {
                (self.recovery_latency_ms.mean(), self.recovery_latency_ms.percentile(99.0))
            };
            pairs.push((
                "faults",
                obj(vec![
                    ("arrivals", self.arrivals.into()),
                    ("worker_crashes", self.worker_crashes.into()),
                    ("worker_recoveries", self.worker_recoveries.into()),
                    ("failed", self.failed.into()),
                    ("retried", self.retried.into()),
                    ("hedged", self.hedged.into()),
                    ("re_routed", self.re_routed.into()),
                    ("migrated", self.migrated.into()),
                    ("init_failures", self.init_failures.into()),
                    ("recovery_mean_ms", num_or_null(rec_mean)),
                    ("recovery_p99_ms", num_or_null(rec_p99)),
                ]),
            ));
        }
        // Slot-agnostic runs (the default) emit no slot keys, so their
        // summaries stay byte-identical to the pre-slot engine.
        if self.slots_enabled {
            let short_n = self.hol_wait_short_ms.seen();
            let long_n = self.hol_wait_long_ms.seen();
            let short_p99 = self.hol_wait_p99_ms(true);
            let long_p99 = self.hol_wait_p99_ms(false);
            let peak_busy = self.slot_timeline.iter().map(|&(_, b)| b).max().unwrap_or(0);
            pairs.push((
                "slots",
                obj(vec![
                    ("rebound", self.rebound.into()),
                    ("hol_short_n", short_n.into()),
                    ("hol_long_n", long_n.into()),
                    ("hol_short_p99_ms", num_or_null(short_p99)),
                    ("hol_long_p99_ms", num_or_null(long_p99)),
                    ("peak_busy_slots", (peak_busy as u64).into()),
                ]),
            ));
        }
        obj(pairs)
    }
}

/// Sum two non-negative step functions given as (time, value) breakpoint
/// lists (each list's value holds from its breakpoint until the next; 0
/// before the first breakpoint). Duplicate times within a list resolve to
/// the last entry, matching how `record_scale` appends.
fn merge_timelines(a: &[(f64, usize)], b: &[(f64, usize)]) -> Vec<(f64, usize)> {
    if a.is_empty() {
        return b.to_vec();
    }
    if b.is_empty() {
        return a.to_vec();
    }
    let mut out: Vec<(f64, usize)> = Vec::with_capacity(a.len() + b.len());
    let (mut i, mut j) = (0usize, 0usize);
    let (mut va, mut vb) = (0usize, 0usize);
    while i < a.len() || j < b.len() {
        let ta = a.get(i).map(|p| p.0).unwrap_or(f64::INFINITY);
        let tb = b.get(j).map(|p| p.0).unwrap_or(f64::INFINITY);
        let t = ta.min(tb);
        while i < a.len() && a[i].0 == t {
            va = a[i].1;
            i += 1;
        }
        while j < b.len() && b[j].0 == t {
            vb = b[j].1;
            j += 1;
        }
        out.push((t, va + vb));
    }
    out
}

/// Aggregate over the paper's 20 repeated runs: mean of each scalar metric.
#[derive(Clone, Debug, Default)]
pub struct Aggregate {
    /// Mean latency (ms) across runs.
    pub mean_latency_ms: OnlineStats,
    /// p90 latency (ms) across runs.
    pub p90_ms: OnlineStats,
    /// p95 latency (ms) across runs.
    pub p95_ms: OnlineStats,
    /// p99 latency (ms) across runs.
    pub p99_ms: OnlineStats,
    /// Cold-start rate across runs.
    pub cold_rate: OnlineStats,
    /// Admission reject rate across runs.
    pub reject_rate: OnlineStats,
    /// Load-imbalance CV across runs.
    pub mean_cv: OnlineStats,
    /// Completed requests across runs.
    pub completed: OnlineStats,
    /// Requests/s across runs.
    pub rps: OnlineStats,
    /// Worker-seconds (cost proxy) across runs.
    pub worker_seconds: OnlineStats,
    /// Pre-warm speculation hit rate across runs.
    pub prewarm_hit_rate: OnlineStats,
}

impl Aggregate {
    /// An empty aggregate.
    pub fn new() -> Self {
        Default::default()
    }

    /// Fold one run's scalar metrics into the aggregate.
    pub fn add(&mut self, run: &mut RunMetrics) {
        self.mean_latency_ms.push(run.mean_latency_ms());
        self.p90_ms.push(run.latency_percentile_ms(90.0));
        self.p95_ms.push(run.latency_percentile_ms(95.0));
        self.p99_ms.push(run.latency_percentile_ms(99.0));
        self.cold_rate.push(run.cold_rate());
        self.reject_rate.push(run.reject_rate());
        self.mean_cv.push(run.mean_cv());
        self.completed.push(run.completed as f64);
        self.rps.push(run.rps());
        self.worker_seconds.push(run.worker_seconds);
        self.prewarm_hit_rate.push(run.prewarm_hit_rate());
    }

    /// Runs folded in so far.
    pub fn runs(&self) -> u64 {
        self.mean_latency_ms.count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_derive() {
        let mut m = RunMetrics::new("hiku", 2, 10, 10.0);
        m.record_assignment(0, 0.5);
        m.record_assignment(1, 0.6);
        m.record_response(0.100, true, 0.0, 1.0);
        m.record_response(0.050, false, 0.01, 2.0);
        assert_eq!(m.completed, 2);
        assert!((m.cold_rate() - 0.5).abs() < 1e-12);
        assert!((m.mean_latency_ms() - 75.0).abs() < 1e-9);
        assert!((m.rps() - 0.2).abs() < 1e-12);
        let j = m.summary_json();
        assert_eq!(j.get("cold_starts").unwrap().as_u64(), Some(1));
    }

    #[test]
    fn scaling_timeline_integrates_worker_seconds() {
        let mut m = RunMetrics::new("hiku", 2, 10, 100.0);
        m.record_scale(0.0, 2);
        m.record_scale(10.0, 3); // 2 workers x 10 s
        m.record_scale(40.0, 2); // 3 workers x 30 s
        m.finalize_scaling(100.0); // 2 workers x 60 s
        assert!((m.worker_seconds - (20.0 + 90.0 + 120.0)).abs() < 1e-9);
        assert_eq!(m.scale_event_count(), 2, "terminal point is not an event");
        assert_eq!(m.scaling_timeline.last(), Some(&(100.0, 2)));
    }

    #[test]
    fn prewarm_hit_rate_bounds() {
        let mut m = RunMetrics::new("hiku", 1, 1, 1.0);
        assert_eq!(m.prewarm_hit_rate(), 0.0, "no speculation -> rate 0");
        m.prewarm_spawned = 4;
        m.prewarm_hits = 3;
        assert!((m.prewarm_hit_rate() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn reject_accounting() {
        let mut m = RunMetrics::new("hiku", 2, 10, 10.0);
        assert_eq!(m.reject_rate(), 0.0, "no traffic -> rate 0");
        m.record_assignment(0, 0.5);
        m.record_response(0.1, false, 0.0, 1.0);
        m.record_reject(4);
        m.record_reject(4);
        m.record_enqueue(1);
        m.record_enqueue(3);
        m.record_pending_wait(7, 0.2);
        m.record_pending_depth(1.0, 3);
        assert_eq!(m.rejected, 2);
        assert_eq!(m.reject_count_fn(4), 2, "rejects attribute to their function");
        assert_eq!(m.reject_count_fn(0), 0);
        assert_eq!(m.enqueued, 2);
        assert_eq!(m.peak_pending, 3);
        assert!((m.reject_rate() - 2.0 / 3.0).abs() < 1e-12);
        assert!((m.pending_wait_p99_fn_ms(7) - 200.0).abs() < 1e-9);
        assert_eq!(m.pending_wait_p99_fn_ms(0), 0.0, "never-parked function reports 0");
        // Rejects never contaminate the latency samples.
        assert_eq!(m.latency_ms.seen(), 1);
        let j = m.summary_json();
        assert_eq!(j.get("rejected").unwrap().as_u64(), Some(2));
        assert!(j.get("reject_rate").unwrap().as_f64().unwrap() > 0.6);
        assert_eq!(j.get("peak_pending").unwrap().as_u64(), Some(3));
        // Per-function breakdowns surface as sparse [id, value] pairs.
        let rej = j.get("rejects_by_fn").unwrap();
        assert_eq!(rej.to_string_compact(), "[[4,2]]");
        assert!(j.get("p99_pending_wait_by_fn_ms").is_some());
        // Merge sums the new counters and unions the wait samples,
        // per-function tables included.
        let mut b = RunMetrics::new("hiku", 2, 10, 10.0);
        b.record_reject(9);
        b.record_enqueue(5);
        b.record_pending_wait(7, 0.4);
        b.stolen = 1;
        m.merge(&b);
        assert_eq!(m.rejected, 3);
        assert_eq!(m.reject_count_fn(4), 2);
        assert_eq!(m.reject_count_fn(9), 1);
        assert_eq!(m.enqueued, 3);
        assert_eq!(m.stolen, 1);
        assert_eq!(m.peak_pending, 8);
        assert_eq!(m.pending_wait_ms.seen(), 2);
        assert_eq!(m.pending_wait_by_fn_ms[7].seen(), 2);
    }

    #[test]
    fn merge_unions_samples_and_sums_timelines() {
        // Shard 0: 2 workers, one cold response; shard 1: 1 worker, one
        // warm response and a scale event.
        let mut a = RunMetrics::new("hiku", 2, 10, 100.0);
        a.record_scale(0.0, 2);
        a.record_assignment(0, 1.0);
        a.record_response(0.100, true, 0.0, 2.0);
        a.finalize_scaling(100.0); // 2 x 100 = 200 worker-seconds
        let mut b = RunMetrics::new("hiku", 1, 10, 100.0);
        b.record_scale(0.0, 1);
        b.record_assignment(0, 1.5);
        b.record_response(0.300, false, 0.01, 3.0);
        b.record_scale(50.0, 2); // 1 x 50 + 2 x 50 = 150 worker-seconds
        b.finalize_scaling(100.0);
        a.merge(&b);
        assert_eq!(a.completed, 2);
        assert_eq!(a.issued, 2);
        assert_eq!(a.cold_starts, 1);
        assert_eq!(a.warm_starts, 1);
        assert!((a.mean_latency_ms() - 200.0).abs() < 1e-9);
        assert!((a.cold_rate() - 0.5).abs() < 1e-12);
        // Timeline: 3 workers from t=0, 4 from t=50; integral 200 + 150.
        assert!((a.worker_seconds - 350.0).abs() < 1e-9);
        assert_eq!(a.scaling_timeline.first(), Some(&(0.0, 3)));
        assert!(a.scaling_timeline.contains(&(50.0, 4)));
        assert_eq!(a.scaling_timeline.last(), Some(&(100.0, 4)));
        assert_eq!(a.scale_event_count(), 1, "only the t=50 step changes the count");
        // Worker series appended: shard 0's workers then shard 1's.
        assert_eq!(a.imbalance.totals().len(), 3);
        assert_eq!(a.imbalance.totals(), vec![1.0, 0.0, 1.0]);
    }

    #[test]
    fn empty_run_summary_emits_null_not_nan() {
        let mut m = RunMetrics::new("hiku", 2, 10, 10.0);
        let j = m.summary_json();
        assert_eq!(j.get("mean_latency_ms"), Some(&Json::Null));
        assert_eq!(j.get("p50_ms"), Some(&Json::Null));
        assert_eq!(j.get("p99_ms"), Some(&Json::Null));
        assert_eq!(j.get("mean_queue_delay_ms"), Some(&Json::Null));
        // The serialized summary must be valid JSON — NaN/inf are not.
        let s = j.to_string_compact();
        assert!(Json::parse(&s).is_ok(), "summary must round-trip: {s}");
        // Default telemetry adds no extra keys.
        assert!(j.get("sketch").is_none());
        assert!(j.get("phases").is_none());
        assert!(j.get("trace_spans").is_none());
        // Fault-free runs emit no fault keys (byte-identity contract).
        assert!(j.get("faults").is_none());
        // Slot-agnostic runs emit no slot keys either.
        assert!(j.get("slots").is_none());
    }

    #[test]
    fn slots_block_gated_and_merged() {
        let mut m = RunMetrics::new("hiku", 2, 10, 10.0);
        // HoL waits are recorded unconditionally (cheap, perturbs nothing)
        // but reported only when the slot gate is set.
        m.record_hol_wait(true, 0.050);
        m.record_hol_wait(false, 0.400);
        assert!(m.summary_json().get("slots").is_none(), "gate off: no slot keys");
        m.slots_enabled = true;
        m.rebound = 2;
        m.record_slot_depth(1.0, 3);
        m.record_slot_depth(2.0, 5);
        let j = m.summary_json();
        let sb = j.get("slots").expect("slots block present when enabled");
        assert_eq!(sb.get("rebound").unwrap().as_u64(), Some(2));
        assert_eq!(sb.get("hol_short_n").unwrap().as_u64(), Some(1));
        assert_eq!(sb.get("hol_long_n").unwrap().as_u64(), Some(1));
        assert!((sb.get("hol_short_p99_ms").unwrap().as_f64().unwrap() - 50.0).abs() < 1e-9);
        assert_eq!(sb.get("peak_busy_slots").unwrap().as_u64(), Some(5));
        assert!((m.hol_wait_p99_ms(false) - 400.0).abs() < 1e-9);
        assert!(m.hol_wait_p99_ms(true) > 0.0);
        // Merge ORs the gate, sums rebinds, unions waits, sums timelines.
        let mut b = RunMetrics::new("hiku", 2, 10, 10.0);
        b.slots_enabled = true;
        b.rebound = 1;
        b.record_hol_wait(true, 0.010);
        b.record_slot_depth(1.0, 2);
        let mut c = RunMetrics::new("hiku", 2, 10, 10.0);
        c.merge(&m);
        c.merge(&b);
        assert!(c.slots_enabled);
        assert_eq!(c.rebound, 3);
        assert_eq!(c.hol_wait_short_ms.seen(), 2);
        assert!(c.slot_timeline.contains(&(1.0, 5)), "timelines sum as step functions");
    }

    #[test]
    fn faults_block_gated_and_merged() {
        let mut m = RunMetrics::new("hiku", 2, 10, 10.0);
        m.faults_enabled = true;
        m.arrivals = 10;
        m.worker_crashes = 1;
        m.failed = 2;
        m.retried = 3;
        m.recovery_latency_ms.push(120.0);
        let j = m.summary_json();
        let fb = j.get("faults").expect("faults block present when enabled");
        assert_eq!(fb.get("failed").unwrap().as_u64(), Some(2));
        assert_eq!(fb.get("retried").unwrap().as_u64(), Some(3));
        assert_eq!(fb.get("arrivals").unwrap().as_u64(), Some(10));
        assert!(fb.get("recovery_p99_ms").unwrap().as_f64().unwrap() > 100.0);
        // Merge sums counters and ORs the gate (sharded fault runs).
        let mut b = RunMetrics::new("hiku", 2, 10, 10.0);
        b.failed = 1;
        b.retried = 2;
        b.arrivals = 5;
        b.hedged = 1;
        b.migrated = 4;
        m.merge(&b);
        assert!(m.faults_enabled);
        assert_eq!(m.failed, 3);
        assert_eq!(m.retried, 5);
        assert_eq!(m.arrivals, 15);
        assert_eq!(m.hedged, 1);
        assert_eq!(m.migrated, 4);
        // An empty recovery distribution reports null, not NaN.
        let mut e = RunMetrics::new("hiku", 1, 1, 1.0);
        e.faults_enabled = true;
        let je = e.summary_json();
        assert_eq!(je.get("faults").unwrap().get("recovery_p99_ms"), Some(&Json::Null));
        assert!(Json::parse(&je.to_string_compact()).is_ok());
    }

    #[test]
    fn sketch_mode_summary_marks_itself() {
        let tel = TelemetryConfig { sketch: true, ..Default::default() };
        let mut m = RunMetrics::with_telemetry("hiku", 2, 10, 10.0, &tel);
        m.record_response(0.1, false, 0.0, 1.0);
        m.record_pending_wait(3, 0.2);
        let j = m.summary_json();
        assert_eq!(j.get("sketch").and_then(|v| v.as_bool()), Some(true));
        assert!(m.latency_ms.is_sketch());
        assert!(m.pending_wait_by_fn_ms[3].is_sketch(), "lazy tables inherit the mode");
    }

    #[test]
    fn trace_sampling_is_deterministic_and_capped() {
        let tel = TelemetryConfig { trace_sample: 2, trace_max: 4, ..Default::default() };
        let mut a = RunMetrics::with_telemetry("hiku", 1, 1, 1.0, &tel);
        let mut b = RunMetrics::with_telemetry("hiku", 1, 1, 1.0, &tel);
        for rid in 0..100u64 {
            a.trace.record(rid, 0, "arrival", 0.1, 0.1, None, "");
            b.trace.record(rid, 0, "arrival", 0.1, 0.1, None, "");
        }
        assert_eq!(a.trace.len(), 4, "span cap bounds memory");
        assert!(a.trace.truncated() > 0);
        assert_eq!(a.trace.spans(), b.trace.spans(), "hash gate is deterministic");
        // An untraced collector records nothing and costs nothing.
        let mut off = RunMetrics::new("hiku", 1, 1, 1.0);
        off.trace.record(0, 0, "arrival", 0.0, 0.0, None, "");
        assert!(off.trace.is_empty());
    }

    #[test]
    fn phase_profile_merges_and_reports_fractions() {
        let mut p = PhaseProfile::new(true);
        p.pop_s = 1.0;
        p.decide_s = 2.0;
        p.wall_s = 4.0;
        let mut q = PhaseProfile::new(true);
        q.pop_s = 1.0;
        q.barrier_s = 2.0;
        q.wall_s = 4.0;
        p.merge_add(&q);
        assert!((p.frac(p.pop_s) - 0.25).abs() < 1e-12);
        assert!((p.frac(p.decide_s) - 0.25).abs() < 1e-12);
        let j = p.json();
        assert!(j.get("pop_frac").unwrap().as_f64().unwrap() > 0.0);
        // Zero wall time never divides by zero.
        let z = PhaseProfile::new(true);
        assert_eq!(z.frac(z.pop_s), 0.0);
    }

    #[test]
    fn aggregate_over_runs() {
        let mut agg = Aggregate::new();
        for seed in 0..3 {
            let mut m = RunMetrics::new("x", 2, 10, 10.0);
            m.record_response(0.1 * (seed + 1) as f64, seed == 0, 0.0, 1.0);
            agg.add(&mut m);
        }
        assert_eq!(agg.runs(), 3);
        assert!((agg.mean_latency_ms.mean() - 200.0).abs() < 1e-9);
        assert!((agg.cold_rate.mean() - 1.0 / 3.0).abs() < 1e-9);
    }
}
