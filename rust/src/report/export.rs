//! Figure-data export: CSV series for external plotting (gnuplot,
//! matplotlib). Each function mirrors one of the paper's figures and
//! writes the same series the figure plots.

use crate::metrics::RunMetrics;
use crate::stats::Dist;
use crate::util::json::{obj, Json};
use std::fmt::Write as _;

/// Escape a CSV cell (quotes + commas).
fn cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Generic CSV writer: header + rows.
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&header.iter().map(|h| cell(h)).collect::<Vec<_>>().join(","));
    out.push('\n');
    for r in rows {
        out.push_str(&r.iter().map(|c| cell(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

/// Fig 10: latency CDF — columns (scheduler, latency_ms, cum_prob).
/// Works in both storage modes: exact runs pool the raw samples (the
/// pre-telemetry output, bit for bit); sketch runs merge the sketches and
/// read the quantile grid within the configured relative error.
pub fn latency_cdf_csv(runs: &mut [(String, Vec<RunMetrics>)], points: usize) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs.iter_mut() {
        let mut pooled: Option<Dist> = None;
        for m in ms.iter() {
            match pooled.as_mut() {
                None => pooled = Some(m.latency_ms.clone()),
                Some(p) => p.merge_from(&m.latency_ms),
            }
        }
        let Some(mut pooled) = pooled else { continue };
        for (v, q) in pooled.cdf(points) {
            rows.push(vec![sched.clone(), format!("{v:.3}"), format!("{q:.4}")]);
        }
    }
    to_csv(&["scheduler", "latency_ms", "cum_prob"], &rows)
}

/// Fig 14: CV-over-time series — columns (scheduler, second, cv).
pub fn cv_series_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for (sec, cv) in m.imbalance.cv_series().iter().enumerate() {
                rows.push(vec![sched.clone(), sec.to_string(), format!("{cv:.4}")]);
            }
        }
    }
    to_csv(&["scheduler", "second", "cv"], &rows)
}

/// Fig 16: cumulative throughput — columns (scheduler, second, cumulative).
pub fn cumulative_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for (sec, total) in m.throughput.cumulative().iter().enumerate() {
                rows.push(vec![sched.clone(), sec.to_string(), format!("{total:.0}")]);
            }
        }
    }
    to_csv(&["scheduler", "second", "cumulative_requests"], &rows)
}

/// Autoscale timeline — columns (scheduler, time_s, active_workers). One
/// series per scheduler (first run); static runs contribute the initial
/// and terminal points only.
pub fn scaling_timeline_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for &(t, active) in &m.scaling_timeline {
                rows.push(vec![sched.clone(), format!("{t:.3}"), active.to_string()]);
            }
        }
    }
    to_csv(&["scheduler", "time_s", "active_workers"], &rows)
}

/// Format a float cell, or an empty cell for a non-finite value (an empty
/// run has NaN percentiles — `NaN` must never leak into the CSV, where it
/// silently poisons downstream column parsers).
fn num(x: f64, prec: usize) -> String {
    if x.is_finite() {
        format!("{x:.prec$}")
    } else {
        String::new()
    }
}

/// Summary table (Figs 11/12/13/15/17 scalars plus the dispatch-protocol
/// admission columns) — one row per run. Rejected requests are reported
/// explicitly: they are excluded from the latency percentiles by
/// construction, so the rate column is the only place they surface.
/// Non-finite scalars (an empty run) export as empty cells, not `NaN`.
pub fn summary_csv(runs: &mut [(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs.iter_mut() {
        for (i, m) in ms.iter_mut().enumerate() {
            rows.push(vec![
                sched.clone(),
                i.to_string(),
                m.vus.to_string(),
                num(m.mean_latency_ms(), 2),
                num(m.latency_percentile_ms(90.0), 2),
                num(m.latency_percentile_ms(95.0), 2),
                num(m.latency_percentile_ms(99.0), 2),
                num(m.cold_rate(), 4),
                num(m.mean_cv(), 4),
                m.completed.to_string(),
                num(m.rps(), 2),
                m.rejected.to_string(),
                num(m.reject_rate(), 4),
                m.enqueued.to_string(),
                num(m.mean_pending_wait_ms(), 2),
            ]);
        }
    }
    to_csv(
        &[
            "scheduler", "run", "vus", "mean_ms", "p90_ms", "p95_ms", "p99_ms", "cold_rate",
            "mean_cv", "completed", "rps", "rejected", "reject_rate", "enqueued",
            "mean_pending_wait_ms",
        ],
        &rows,
    )
}

/// Dispatch-fairness breakdown — columns (scheduler, run, function,
/// rejected, parked, mean_wait_ms, p99_wait_ms), one row per function
/// that was rejected or parked at least once. This is the per-function
/// view behind the pooled `rejected`/`mean_pending_wait_ms` scalars: a
/// monopolizing function shows up as a single heavy row instead of
/// disappearing into the pool, and per-function caps show their reject
/// isolation here. Push-mode runs contribute no rows.
pub fn per_function_csv(runs: &mut [(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs.iter_mut() {
        for (i, m) in ms.iter_mut().enumerate() {
            let functions =
                m.rejected_by_fn.len().max(m.pending_wait_by_fn_ms.len());
            for f in 0..functions {
                let rejected = m.reject_count_fn(f);
                let parked =
                    m.pending_wait_by_fn_ms.get(f).map(|s| s.seen()).unwrap_or(0);
                if rejected == 0 && parked == 0 {
                    continue;
                }
                let mean = m
                    .pending_wait_by_fn_ms
                    .get(f)
                    .filter(|s| !s.is_empty())
                    .map(|s| s.mean())
                    .unwrap_or(0.0);
                let p99 = m.pending_wait_p99_fn_ms(f);
                rows.push(vec![
                    sched.clone(),
                    i.to_string(),
                    f.to_string(),
                    rejected.to_string(),
                    parked.to_string(),
                    format!("{mean:.2}"),
                    format!("{p99:.2}"),
                ]);
            }
        }
    }
    to_csv(
        &["scheduler", "run", "function", "rejected", "parked", "mean_wait_ms", "p99_wait_ms"],
        &rows,
    )
}

/// Dispatch-protocol pending-depth timeline — columns
/// (scheduler, time_s, pending). One series per scheduler (first run);
/// push-mode runs contribute no rows (the timeline is pull-only).
pub fn pending_depth_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for &(t, depth) in &m.pending_timeline {
                rows.push(vec![sched.clone(), format!("{t:.3}"), depth.to_string()]);
            }
        }
    }
    to_csv(&["scheduler", "time_s", "pending"], &rows)
}

/// Request-lifecycle trace — columns (request, function, shard, phase,
/// start_s, end_s, worker, detail), one row per recorded span in (shard,
/// record) order. The `worker` cell is empty for spans not bound to a
/// worker (arrival, pending). Times are virtual seconds under the
/// simulator and wall-clock seconds since start under the server; the
/// span taxonomy is identical (DESIGN.md §9).
pub fn trace_csv(m: &RunMetrics) -> String {
    let rows: Vec<Vec<String>> = m
        .trace
        .spans()
        .iter()
        .map(|s| {
            vec![
                s.request.to_string(),
                s.function.to_string(),
                s.shard.to_string(),
                s.phase.to_string(),
                format!("{:.6}", s.start_s),
                format!("{:.6}", s.end_s),
                s.worker.map(|w| w.to_string()).unwrap_or_default(),
                s.detail.clone(),
            ]
        })
        .collect();
    to_csv(
        &["request", "function", "shard", "phase", "start_s", "end_s", "worker", "detail"],
        &rows,
    )
}

/// The same trace as a Chrome-trace document (the `chrome://tracing` /
/// Perfetto "traceEvents" JSON array format): one complete (`"ph": "X"`)
/// event per span with `ts`/`dur` in microseconds, `pid` = shard and
/// `tid` = function, so tracks group by shard and lane by function type.
/// Instant spans (arrival, decide, bind, complete) render as zero-width
/// slices, which the viewers draw as ticks.
pub fn chrome_trace_json(m: &RunMetrics) -> Json {
    let events: Vec<Json> = m
        .trace
        .spans()
        .iter()
        .map(|s| {
            let mut args = vec![("request", Json::from(s.request))];
            if let Some(w) = s.worker {
                args.push(("worker", w.into()));
            }
            if !s.detail.is_empty() {
                args.push(("detail", s.detail.as_str().into()));
            }
            obj(vec![
                ("name", s.phase.into()),
                ("cat", "request".into()),
                ("ph", "X".into()),
                ("ts", (s.start_s * 1e6).into()),
                ("dur", ((s.end_s - s.start_s).max(0.0) * 1e6).into()),
                ("pid", s.shard.into()),
                ("tid", s.function.into()),
                ("args", obj(args)),
            ])
        })
        .collect();
    obj(vec![("traceEvents", events.into()), ("displayTimeUnit", "ms".into())])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::report::run_cell;

    fn tiny_runs() -> Vec<(String, Vec<RunMetrics>)> {
        let mut cfg = Config::default();
        cfg.workload.duration_s = 8.0;
        ["hiku", "random"]
            .iter()
            .map(|s| {
                let (_, runs) = run_cell(&cfg, s, 5, 2).unwrap();
                (s.to_string(), runs)
            })
            .collect()
    }

    #[test]
    fn csv_escaping() {
        let out = to_csv(&["a", "b"], &[vec!["x,y".into(), "q\"z".into()]]);
        assert_eq!(out, "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    fn cdf_csv_well_formed() {
        let mut runs = tiny_runs();
        let csv = latency_cdf_csv(&mut runs, 10);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "scheduler,latency_ms,cum_prob");
        assert_eq!(lines.len(), 1 + 2 * 10);
        assert!(lines[1].starts_with("hiku,"));
        // Columns parse as numbers.
        for l in &lines[1..] {
            let cols: Vec<&str> = l.split(',').collect();
            assert_eq!(cols.len(), 3);
            cols[1].parse::<f64>().unwrap();
            cols[2].parse::<f64>().unwrap();
        }
    }

    #[test]
    fn summary_csv_one_row_per_run() {
        let mut runs = tiny_runs();
        let csv = summary_csv(&mut runs);
        assert_eq!(csv.lines().count(), 1 + 4, "2 schedulers x 2 runs + header");
        assert!(csv.contains("mean_ms"));
        assert!(csv.contains("reject_rate"), "admission columns must export");
        // Push-mode runs: zero rejects, zero enqueues, but the columns
        // are present (no silent vanishing).
        let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row.len(), 15);
        assert_eq!(row[11], "0", "rejected count column");
        assert_eq!(row[13], "0", "enqueued column");
    }

    #[test]
    fn pending_depth_csv_empty_for_push_runs() {
        let runs = tiny_runs();
        let csv = pending_depth_csv(&runs);
        assert_eq!(csv.lines().count(), 1, "push mode has no pending timeline");
        assert_eq!(csv.lines().next().unwrap(), "scheduler,time_s,pending");
    }

    #[test]
    fn per_function_csv_reports_only_active_functions() {
        // Push runs have nothing per-function to report.
        let mut runs = tiny_runs();
        let csv = per_function_csv(&mut runs);
        assert_eq!(csv.lines().count(), 1, "push mode has no per-function rows");
        // Synthetic pull-run metrics: one rejecting function, one parked.
        let mut m = RunMetrics::new("hiku", 2, 5, 10.0);
        m.record_reject(3);
        m.record_reject(3);
        m.record_pending_wait(1, 0.25);
        let mut runs = vec![("hiku".to_string(), vec![m])];
        let csv = per_function_csv(&mut runs);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(
            lines[0],
            "scheduler,run,function,rejected,parked,mean_wait_ms,p99_wait_ms"
        );
        assert_eq!(lines.len(), 3, "one row per active function");
        assert_eq!(lines[1], "hiku,0,1,0,1,250.00,250.00");
        assert_eq!(lines[2], "hiku,0,3,2,0,0.00,0.00");
    }

    #[test]
    fn series_csvs_nonempty() {
        let runs = tiny_runs();
        assert!(cv_series_csv(&runs).lines().count() > 5);
        assert!(cumulative_csv(&runs).lines().count() > 5);
    }

    #[test]
    fn trace_exports_render_spans() {
        use crate::config::TelemetryConfig;
        let tel = TelemetryConfig { trace_sample: 1, trace_max: 16, ..Default::default() };
        let mut m = RunMetrics::with_telemetry("hiku", 2, 1, 10.0, &tel);
        m.trace.record(0, 3, "arrival", 0.5, 0.5, None, "");
        m.trace.record(0, 3, "service", 0.6, 0.9, Some(1), "cold");
        let csv = trace_csv(&m);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "request,function,shard,phase,start_s,end_s,worker,detail");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[1], "0,3,0,arrival,0.500000,0.500000,,");
        assert_eq!(lines[2], "0,3,0,service,0.600000,0.900000,1,cold");
        // The Chrome-trace document round-trips through the JSON parser
        // and carries one complete event per span.
        let doc = chrome_trace_json(&m);
        let parsed = Json::parse(&doc.to_string_compact()).unwrap();
        let events = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(events.len(), 2);
        assert_eq!(events[0].get("ph").unwrap().as_str(), Some("X"));
        let dur = events[1].get("dur").unwrap().as_f64().unwrap();
        assert!((dur - 3.0e5).abs() < 1.0, "dur should be ~300ms in us: {dur}");
        assert_eq!(events[1].at(&["args", "detail"]).unwrap().as_str(), Some("cold"));
    }

    #[test]
    fn summary_csv_empty_run_has_no_nan_cells() {
        let m = RunMetrics::new("hiku", 2, 1, 10.0);
        let mut runs = vec![("hiku".to_string(), vec![m])];
        let csv = summary_csv(&mut runs);
        assert!(!csv.contains("NaN"), "non-finite scalars must export empty:\n{csv}");
        let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row.len(), 15, "empty cells must not drop columns");
        assert_eq!(row[3], "", "mean_ms of an empty run is an empty cell");
    }

    #[test]
    fn scaling_timeline_csv_has_initial_points() {
        let runs = tiny_runs();
        let csv = scaling_timeline_csv(&runs);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "scheduler,time_s,active_workers");
        // Static runs: initial + terminal point per scheduler.
        assert!(lines.len() >= 1 + 2 * runs.len(), "{csv}");
        assert!(lines[1].starts_with("hiku,0.000,"));
    }
}
