//! Figure-data export: CSV series for external plotting (gnuplot,
//! matplotlib). Each function mirrors one of the paper's figures and
//! writes the same series the figure plots.

use crate::metrics::RunMetrics;
use crate::stats::Samples;
use std::fmt::Write as _;

/// Escape a CSV cell (quotes + commas).
fn cell(s: &str) -> String {
    if s.contains(',') || s.contains('"') || s.contains('\n') {
        format!("\"{}\"", s.replace('"', "\"\""))
    } else {
        s.to_string()
    }
}

/// Generic CSV writer: header + rows.
pub fn to_csv(header: &[&str], rows: &[Vec<String>]) -> String {
    let mut out = String::new();
    out.push_str(&header.iter().map(|h| cell(h)).collect::<Vec<_>>().join(","));
    out.push('\n');
    for r in rows {
        out.push_str(&r.iter().map(|c| cell(c)).collect::<Vec<_>>().join(","));
        out.push('\n');
    }
    out
}

/// Fig 10: latency CDF — columns (scheduler, latency_ms, cum_prob).
pub fn latency_cdf_csv(runs: &mut [(String, Vec<RunMetrics>)], points: usize) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs.iter_mut() {
        let mut pooled = Samples::new();
        for m in ms.iter_mut() {
            for &v in m.latency_ms.values() {
                pooled.push(v);
            }
        }
        for (v, q) in pooled.cdf(points) {
            rows.push(vec![sched.clone(), format!("{v:.3}"), format!("{q:.4}")]);
        }
    }
    to_csv(&["scheduler", "latency_ms", "cum_prob"], &rows)
}

/// Fig 14: CV-over-time series — columns (scheduler, second, cv).
pub fn cv_series_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for (sec, cv) in m.imbalance.cv_series().iter().enumerate() {
                rows.push(vec![sched.clone(), sec.to_string(), format!("{cv:.4}")]);
            }
        }
    }
    to_csv(&["scheduler", "second", "cv"], &rows)
}

/// Fig 16: cumulative throughput — columns (scheduler, second, cumulative).
pub fn cumulative_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for (sec, total) in m.throughput.cumulative().iter().enumerate() {
                rows.push(vec![sched.clone(), sec.to_string(), format!("{total:.0}")]);
            }
        }
    }
    to_csv(&["scheduler", "second", "cumulative_requests"], &rows)
}

/// Autoscale timeline — columns (scheduler, time_s, active_workers). One
/// series per scheduler (first run); static runs contribute the initial
/// and terminal points only.
pub fn scaling_timeline_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for &(t, active) in &m.scaling_timeline {
                rows.push(vec![sched.clone(), format!("{t:.3}"), active.to_string()]);
            }
        }
    }
    to_csv(&["scheduler", "time_s", "active_workers"], &rows)
}

/// Summary table (Figs 11/12/13/15/17 scalars plus the dispatch-protocol
/// admission columns) — one row per run. Rejected requests are reported
/// explicitly: they are excluded from the latency percentiles by
/// construction, so the rate column is the only place they surface.
pub fn summary_csv(runs: &mut [(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs.iter_mut() {
        for (i, m) in ms.iter_mut().enumerate() {
            rows.push(vec![
                sched.clone(),
                i.to_string(),
                m.vus.to_string(),
                format!("{:.2}", m.mean_latency_ms()),
                format!("{:.2}", m.latency_percentile_ms(90.0)),
                format!("{:.2}", m.latency_percentile_ms(95.0)),
                format!("{:.2}", m.latency_percentile_ms(99.0)),
                format!("{:.4}", m.cold_rate()),
                format!("{:.4}", m.mean_cv()),
                m.completed.to_string(),
                format!("{:.2}", m.rps()),
                m.rejected.to_string(),
                format!("{:.4}", m.reject_rate()),
                m.enqueued.to_string(),
                format!("{:.2}", m.mean_pending_wait_ms()),
            ]);
        }
    }
    to_csv(
        &[
            "scheduler", "run", "vus", "mean_ms", "p90_ms", "p95_ms", "p99_ms", "cold_rate",
            "mean_cv", "completed", "rps", "rejected", "reject_rate", "enqueued",
            "mean_pending_wait_ms",
        ],
        &rows,
    )
}

/// Dispatch-fairness breakdown — columns (scheduler, run, function,
/// rejected, parked, mean_wait_ms, p99_wait_ms), one row per function
/// that was rejected or parked at least once. This is the per-function
/// view behind the pooled `rejected`/`mean_pending_wait_ms` scalars: a
/// monopolizing function shows up as a single heavy row instead of
/// disappearing into the pool, and per-function caps show their reject
/// isolation here. Push-mode runs contribute no rows.
pub fn per_function_csv(runs: &mut [(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs.iter_mut() {
        for (i, m) in ms.iter_mut().enumerate() {
            let functions =
                m.rejected_by_fn.len().max(m.pending_wait_by_fn_ms.len());
            for f in 0..functions {
                let rejected = m.reject_count_fn(f);
                let parked =
                    m.pending_wait_by_fn_ms.get(f).map(|s| s.seen()).unwrap_or(0);
                if rejected == 0 && parked == 0 {
                    continue;
                }
                let mean = m
                    .pending_wait_by_fn_ms
                    .get(f)
                    .filter(|s| !s.is_empty())
                    .map(|s| s.mean())
                    .unwrap_or(0.0);
                let p99 = m.pending_wait_p99_fn_ms(f);
                rows.push(vec![
                    sched.clone(),
                    i.to_string(),
                    f.to_string(),
                    rejected.to_string(),
                    parked.to_string(),
                    format!("{mean:.2}"),
                    format!("{p99:.2}"),
                ]);
            }
        }
    }
    to_csv(
        &["scheduler", "run", "function", "rejected", "parked", "mean_wait_ms", "p99_wait_ms"],
        &rows,
    )
}

/// Dispatch-protocol pending-depth timeline — columns
/// (scheduler, time_s, pending). One series per scheduler (first run);
/// push-mode runs contribute no rows (the timeline is pull-only).
pub fn pending_depth_csv(runs: &[(String, Vec<RunMetrics>)]) -> String {
    let mut rows = Vec::new();
    for (sched, ms) in runs {
        if let Some(m) = ms.first() {
            for &(t, depth) in &m.pending_timeline {
                rows.push(vec![sched.clone(), format!("{t:.3}"), depth.to_string()]);
            }
        }
    }
    to_csv(&["scheduler", "time_s", "pending"], &rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;
    use crate::report::run_cell;

    fn tiny_runs() -> Vec<(String, Vec<RunMetrics>)> {
        let mut cfg = Config::default();
        cfg.workload.duration_s = 8.0;
        ["hiku", "random"]
            .iter()
            .map(|s| {
                let (_, runs) = run_cell(&cfg, s, 5, 2).unwrap();
                (s.to_string(), runs)
            })
            .collect()
    }

    #[test]
    fn csv_escaping() {
        let out = to_csv(&["a", "b"], &[vec!["x,y".into(), "q\"z".into()]]);
        assert_eq!(out, "a,b\n\"x,y\",\"q\"\"z\"\n");
    }

    #[test]
    fn cdf_csv_well_formed() {
        let mut runs = tiny_runs();
        let csv = latency_cdf_csv(&mut runs, 10);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "scheduler,latency_ms,cum_prob");
        assert_eq!(lines.len(), 1 + 2 * 10);
        assert!(lines[1].starts_with("hiku,"));
        // Columns parse as numbers.
        for l in &lines[1..] {
            let cols: Vec<&str> = l.split(',').collect();
            assert_eq!(cols.len(), 3);
            cols[1].parse::<f64>().unwrap();
            cols[2].parse::<f64>().unwrap();
        }
    }

    #[test]
    fn summary_csv_one_row_per_run() {
        let mut runs = tiny_runs();
        let csv = summary_csv(&mut runs);
        assert_eq!(csv.lines().count(), 1 + 4, "2 schedulers x 2 runs + header");
        assert!(csv.contains("mean_ms"));
        assert!(csv.contains("reject_rate"), "admission columns must export");
        // Push-mode runs: zero rejects, zero enqueues, but the columns
        // are present (no silent vanishing).
        let row: Vec<&str> = csv.lines().nth(1).unwrap().split(',').collect();
        assert_eq!(row.len(), 15);
        assert_eq!(row[11], "0", "rejected count column");
        assert_eq!(row[13], "0", "enqueued column");
    }

    #[test]
    fn pending_depth_csv_empty_for_push_runs() {
        let runs = tiny_runs();
        let csv = pending_depth_csv(&runs);
        assert_eq!(csv.lines().count(), 1, "push mode has no pending timeline");
        assert_eq!(csv.lines().next().unwrap(), "scheduler,time_s,pending");
    }

    #[test]
    fn per_function_csv_reports_only_active_functions() {
        // Push runs have nothing per-function to report.
        let mut runs = tiny_runs();
        let csv = per_function_csv(&mut runs);
        assert_eq!(csv.lines().count(), 1, "push mode has no per-function rows");
        // Synthetic pull-run metrics: one rejecting function, one parked.
        let mut m = RunMetrics::new("hiku", 2, 5, 10.0);
        m.record_reject(3);
        m.record_reject(3);
        m.record_pending_wait(1, 0.25);
        let mut runs = vec![("hiku".to_string(), vec![m])];
        let csv = per_function_csv(&mut runs);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(
            lines[0],
            "scheduler,run,function,rejected,parked,mean_wait_ms,p99_wait_ms"
        );
        assert_eq!(lines.len(), 3, "one row per active function");
        assert_eq!(lines[1], "hiku,0,1,0,1,250.00,250.00");
        assert_eq!(lines[2], "hiku,0,3,2,0,0.00,0.00");
    }

    #[test]
    fn series_csvs_nonempty() {
        let runs = tiny_runs();
        assert!(cv_series_csv(&runs).lines().count() > 5);
        assert!(cumulative_csv(&runs).lines().count() > 5);
    }

    #[test]
    fn scaling_timeline_csv_has_initial_points() {
        let runs = tiny_runs();
        let csv = scaling_timeline_csv(&runs);
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "scheduler,time_s,active_workers");
        // Static runs: initial + terminal point per scheduler.
        assert!(lines.len() >= 1 + 2 * runs.len(), "{csv}");
        assert!(lines[1].starts_with("hiku,0.000,"));
    }
}
