//! Report rendering: regenerate the paper's tables and figure series as
//! text — the same rows/series the paper plots, printed for comparison.
//! CSV export for external plotting lives in [`export`].

pub mod export;

use crate::config::Config;
use crate::metrics::{Aggregate, RunMetrics};
use crate::sim::{run_once, run_trace};
use crate::util::rng::Pcg64;
use crate::workload::azure::{BurstyArrivals, SyntheticTrace};
use crate::workload::loadgen::OpenLoopTrace;

/// Run `runs` seeded repetitions for one (scheduler, vus) cell.
pub fn run_cell(
    base: &Config,
    scheduler: &str,
    vus: usize,
    runs: u64,
) -> Result<(Aggregate, Vec<RunMetrics>), String> {
    let mut cfg = base.clone();
    cfg.scheduler.name = scheduler.to_string();
    cfg.workload.vus = vus;
    let mut agg = Aggregate::new();
    let mut all = Vec::new();
    for r in 0..runs {
        // The paper seeds each run with the experiment start date, shared
        // across schedulers: seed depends on (base seed, run) only.
        let seed = cfg.workload.seed.wrapping_add(r.wrapping_mul(0x9E37_79B9));
        let mut m = run_once(&cfg, seed)?;
        agg.add(&mut m);
        all.push(m);
    }
    Ok((agg, all))
}

/// The evaluation sweep (Figs 10-17 summary table): schedulers x VU levels.
pub fn evaluation_report(
    base: &Config,
    schedulers: &[String],
    vu_levels: &[usize],
    runs: u64,
) -> Result<String, String> {
    let mut out = String::new();
    out.push_str(&format!(
        "# Evaluation sweep: {} workers, {} functions, {} s/run, {} runs/cell\n\n",
        base.cluster.workers,
        base.num_functions(),
        base.workload.duration_s,
        runs
    ));
    out.push_str(&format!(
        "{:<20} {:>4} {:>10} {:>8} {:>8} {:>8} {:>8} {:>6} {:>7} {:>9} {:>8}\n",
        "scheduler", "VUs", "mean(ms)", "p90(ms)", "p95(ms)", "p99(ms)", "cold%", "rej%", "CV",
        "completed", "rps"
    ));
    for &vus in vu_levels {
        for sched in schedulers {
            let (agg, _) = run_cell(base, sched, vus, runs)?;
            out.push_str(&format!(
                "{:<20} {:>4} {:>10.1} {:>8.1} {:>8.1} {:>8.1} {:>6.1}% {:>5.1}% {:>7.3} {:>9.0} {:>8.1}\n",
                sched,
                vus,
                agg.mean_latency_ms.mean(),
                agg.p90_ms.mean(),
                agg.p95_ms.mean(),
                agg.p99_ms.mean(),
                agg.cold_rate.mean() * 100.0,
                agg.reject_rate.mean() * 100.0,
                agg.mean_cv.mean(),
                agg.completed.mean(),
                agg.rps.mean(),
            ));
        }
        out.push('\n');
    }
    Ok(out)
}

/// Figs 4-6: trace characterization report.
pub fn trace_report(universe: usize, duration_s: f64, seed: u64) -> String {
    let tr = SyntheticTrace::generate(universe, duration_s, seed);
    let mut out = String::new();
    out.push_str(&format!(
        "# Azure-like synthetic trace: {} functions, {:.0} min, {} invocations\n\n",
        universe,
        duration_s / 60.0,
        tr.invocations.len()
    ));

    // Fig 4 — skewed popularity.
    out.push_str("## Fig 4 — skewed function popularity\n");
    out.push_str(&format!(
        "top  1% of functions -> {:>5.1}% of invocations (paper: 51.3%)\n",
        tr.top_share(0.01) * 100.0
    ));
    out.push_str(&format!(
        "top 10% of functions -> {:>5.1}% of invocations (paper: 92.3%)\n",
        tr.top_share(0.10) * 100.0
    ));
    out.push_str("cumulative share curve (fraction of functions -> share):\n");
    for (frac, share) in tr.popularity_curve(10) {
        out.push_str(&format!("  {:>5.1}% -> {:>5.1}%\n", frac * 100.0, share * 100.0));
    }

    // Fig 5 — heterogeneous performance.
    out.push_str("\n## Fig 5 — heterogeneous function performance (first 15 functions)\n");
    for (f, mean, std) in tr.exec_heterogeneity(15, seed) {
        out.push_str(&format!(
            "  fn {:>5}: exec {:>8.1} ms +/- {:>7.1} ms\n",
            f,
            mean * 1000.0,
            std * 1000.0
        ));
    }

    // Fig 6 — bursty invocations.
    let (per_min, max_ratio) = tr.interarrival_per_minute();
    out.push_str("\n## Fig 6 — bursty invocations (mean interarrival per minute, ms)\n  ");
    for (i, v) in per_min.iter().enumerate() {
        if v.is_finite() {
            out.push_str(&format!("{v:.1} "));
        }
        if i % 10 == 9 {
            out.push_str("\n  ");
        }
    }
    out.push_str(&format!(
        "\nmax minute-over-minute swing: {max_ratio:.1}x (paper: up to 13.5x)\n"
    ));
    out
}

/// The bursty open-loop trace used by the autoscale bench/report: an
/// Azure-like function mix re-timed with a burstier regime-switching
/// arrival process so the bursts actually hit capacity.
pub fn bursty_trace(num_functions: usize, duration_s: f64, seed: u64) -> OpenLoopTrace {
    let gen = SyntheticTrace::generate(num_functions, duration_s, seed);
    if gen.invocations.is_empty() {
        return OpenLoopTrace::from_synthetic(&[], num_functions.max(1));
    }
    let mut rng = Pcg64::new(seed ^ 0xB125);
    let times = BurstyArrivals { base_rate: 40.0, burst_prob: 0.35, burst_lo: 2.0, burst_hi: 6.0 }
        .generate(duration_s, &mut rng);
    let invocations: Vec<(f64, usize)> = times
        .into_iter()
        .enumerate()
        .map(|(i, t)| (t, gen.invocations[i % gen.invocations.len()].1))
        .collect();
    OpenLoopTrace::from_synthetic(&invocations, num_functions)
}

/// Hot-function monopoly trace for the dispatch fairness experiments
/// (shared by `benches/ablation_dispatch.rs` and `tests/dispatch.rs` so
/// the CI bench gate measures exactly the scenario the tests prove):
/// chameleon (f=0, 392 ms warm) at `hot_rate` req/s plus a pair of dd
/// arrivals (f=1, 549 ms warm) every 0.5 s whose second member parks
/// behind the first.
///
/// With `sharded = true` every load arrival is preceded by a light
/// filler arrival (four linpack copies round-robin — non-overlapping,
/// so the filler shard never parks), making arrival-index parity the
/// 2-shard assignment: even indices feed the pending-free recipient
/// shard 0, odd indices overload the donor shard 1. Deterministic; no
/// RNG involved.
pub fn monopoly_trace(hot_rate: f64, duration_s: f64, sharded: bool) -> OpenLoopTrace {
    const FILLER: [usize; 4] = [5, 13, 21, 29]; // linpack copies, 58 ms warm
    let mut arr: Vec<(f64, usize)> = Vec::new();
    let mut k = 0usize;
    let push = |arr: &mut Vec<(f64, usize)>, k: &mut usize, t: f64, f: usize| {
        if sharded {
            arr.push((t, FILLER[*k % FILLER.len()]));
            *k += 1;
        }
        arr.push((t, f));
    };
    let dt = 1.0 / hot_rate;
    let mut t = 0.05;
    let mut next_bg = 0.30;
    while t < duration_s {
        push(&mut arr, &mut k, t, 0);
        if t >= next_bg {
            push(&mut arr, &mut k, t, 1);
            push(&mut arr, &mut k, t, 1);
            next_bg += 0.5;
        }
        t += dt;
    }
    OpenLoopTrace::from_synthetic(&arr, 40)
}

/// Mixed short/long trace for the core-granularity experiments (shared by
/// `benches/ablation_cores.rs` and `tests/dispatch.rs`, DESIGN.md §11):
/// every 2 s a burst of 24 chameleon arrivals (f=0, 392 ms warm) saturates
/// a 4-worker × 4-slot cluster (16 slots, ~8 waiting), and 50 ms later six
/// linpack arrivals (f=5, 58 ms warm) land in the saturated window.
///
/// Worker-granular dispatch assigns those shorts into per-worker FIFO
/// queues *behind* the overflow longs — head-of-line blocking worth
/// multiple long service times. Core-granular dispatch parks them
/// centrally (late binding): the first freed slot claims them, bounding
/// the short-function p99 wait near one long service time. Deterministic;
/// no RNG involved.
pub fn mixed_class_trace(duration_s: f64) -> OpenLoopTrace {
    let mut arr: Vec<(f64, usize)> = Vec::new();
    let mut t = 0.05;
    while t < duration_s {
        for _ in 0..24 {
            arr.push((t, 0)); // chameleon burst: saturates 16 slots
        }
        for j in 0..6 {
            arr.push((t + 0.05 + 0.01 * j as f64, 5)); // linpack tail
        }
        t += 2.0;
    }
    OpenLoopTrace::from_synthetic(&arr, 40)
}

/// Autoscale policy comparison: policies x schedulers on the bursty trace,
/// reporting the cost/quality trade-off — cold-start rate and latency
/// against worker-seconds (the cost proxy) and pre-warm speculation
/// accuracy. The interesting comparison is `predictive` vs `reactive`:
/// the forecast-driven pools should cut cold starts at comparable
/// worker-seconds.
pub fn autoscale_report(
    base: &Config,
    policies: &[String],
    schedulers: &[String],
    seed: u64,
) -> Result<String, String> {
    let trace = bursty_trace(base.num_functions(), base.workload.duration_s, seed);
    let mut out = String::new();
    out.push_str(&format!(
        "# Autoscale sweep: bursty trace ({} arrivals / {:.0} s), {} start workers, bounds [{}, {}]\n\n",
        trace.len(),
        base.workload.duration_s,
        base.cluster.workers,
        base.autoscale.min_workers,
        base.autoscale.max_workers,
    ));
    out.push_str(&format!(
        "{:<12} {:<20} {:>9} {:>10} {:>9} {:>7} {:>10} {:>7} {:>8}\n",
        "policy", "scheduler", "completed", "mean(ms)", "p95(ms)", "cold%", "worker-s", "scale#", "prewarm%"
    ));
    for policy in policies {
        for sched in schedulers {
            let mut cfg = base.clone();
            cfg.scheduler.name = sched.clone();
            cfg.autoscale.policy = policy.clone();
            if policy == "scheduled" && cfg.autoscale.events.is_empty() {
                // Default demo schedule: one worker joins at 1/4 and at
                // 1/2 of the run.
                cfg.autoscale.events = format!(
                    "{:.0};{:.0}",
                    base.workload.duration_s / 4.0,
                    base.workload.duration_s / 2.0
                );
            }
            let mut m = run_trace(&cfg, &trace, seed)?;
            out.push_str(&format!(
                "{:<12} {:<20} {:>9} {:>10.1} {:>9.1} {:>6.1}% {:>10.0} {:>7} {:>7.1}%\n",
                policy,
                sched,
                m.completed,
                m.mean_latency_ms(),
                m.latency_percentile_ms(95.0),
                m.cold_rate() * 100.0,
                m.worker_seconds,
                m.scale_event_count(),
                m.prewarm_hit_rate() * 100.0,
            ));
        }
        out.push('\n');
    }
    Ok(out)
}

/// Fig 10 — latency CDFs, one series per scheduler (points as text).
pub fn latency_cdf_report(base: &Config, schedulers: &[String], runs: u64, points: usize) -> Result<String, String> {
    let mut out = String::new();
    out.push_str("# Fig 10 — response latency CDF per scheduler\n");
    for sched in schedulers {
        let (_, all) = run_cell(base, sched, base.workload.vus, runs)?;
        // Pool latencies across runs for the CDF (mode-agnostic: exact
        // runs merge sample vectors, sketch runs merge sketches).
        let mut pooled: Option<crate::stats::Dist> = None;
        for m in &all {
            match pooled.as_mut() {
                None => pooled = Some(m.latency_ms.clone()),
                Some(p) => p.merge_from(&m.latency_ms),
            }
        }
        out.push_str(&format!("\n## {sched}\n"));
        if let Some(mut pooled) = pooled {
            for (val, q) in pooled.cdf(points) {
                out.push_str(&format!("  {:>8.1} ms  p={:.3}\n", val, q));
            }
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Config {
        let mut cfg = Config::default();
        cfg.workload.duration_s = 10.0;
        cfg.workload.vus = 5;
        cfg
    }

    #[test]
    fn evaluation_report_renders() {
        let out = evaluation_report(&tiny(), &["hiku".into(), "random".into()], &[5], 2).unwrap();
        assert!(out.contains("hiku"));
        assert!(out.contains("random"));
        assert!(out.contains("cold%"));
    }

    #[test]
    fn trace_report_contains_paper_anchors() {
        let out = trace_report(2000, 300.0, 1);
        assert!(out.contains("Fig 4"));
        assert!(out.contains("paper: 51.3%"));
        assert!(out.contains("Fig 6"));
    }

    #[test]
    fn cdf_report_monotone_series() {
        let out = latency_cdf_report(&tiny(), &["hiku".into()], 1, 10).unwrap();
        assert!(out.matches(" p=").count() >= 10);
    }

    #[test]
    fn bad_scheduler_is_error() {
        assert!(evaluation_report(&tiny(), &["bogus".into()], &[5], 1).is_err());
    }

    #[test]
    fn autoscale_report_renders_all_cells() {
        let mut cfg = tiny();
        cfg.cluster.workers = 2;
        cfg.autoscale.min_workers = 2;
        cfg.autoscale.max_workers = 6;
        let out = autoscale_report(
            &cfg,
            &["none".into(), "reactive".into()],
            &["hiku".into(), "random".into()],
            7,
        )
        .unwrap();
        assert!(out.contains("reactive"));
        assert!(out.contains("worker-s"));
        assert_eq!(out.matches("hiku").count(), 2, "one row per policy");
    }

    #[test]
    fn bursty_trace_is_deterministic_and_bounded() {
        let a = bursty_trace(40, 30.0, 5);
        let b = bursty_trace(40, 30.0, 5);
        assert_eq!(a.arrivals, b.arrivals);
        assert!(!a.is_empty());
        assert!(a.arrivals.iter().all(|&(t, f)| t >= 0.0 && f < 40));
    }
}
