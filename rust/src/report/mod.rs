//! Report rendering: regenerate the paper's tables and figure series as
//! text — the same rows/series the paper plots, printed for comparison.
//! CSV export for external plotting lives in [`export`].

pub mod export;

use crate::config::Config;
use crate::metrics::{Aggregate, RunMetrics};
use crate::sim::run_once;
use crate::workload::azure::SyntheticTrace;

/// Run `runs` seeded repetitions for one (scheduler, vus) cell.
pub fn run_cell(
    base: &Config,
    scheduler: &str,
    vus: usize,
    runs: u64,
) -> Result<(Aggregate, Vec<RunMetrics>), String> {
    let mut cfg = base.clone();
    cfg.scheduler.name = scheduler.to_string();
    cfg.workload.vus = vus;
    let mut agg = Aggregate::new();
    let mut all = Vec::new();
    for r in 0..runs {
        // The paper seeds each run with the experiment start date, shared
        // across schedulers: seed depends on (base seed, run) only.
        let seed = cfg.workload.seed.wrapping_add(r.wrapping_mul(0x9E37_79B9));
        let mut m = run_once(&cfg, seed)?;
        agg.add(&mut m);
        all.push(m);
    }
    Ok((agg, all))
}

/// The evaluation sweep (Figs 10-17 summary table): schedulers x VU levels.
pub fn evaluation_report(
    base: &Config,
    schedulers: &[String],
    vu_levels: &[usize],
    runs: u64,
) -> Result<String, String> {
    let mut out = String::new();
    out.push_str(&format!(
        "# Evaluation sweep: {} workers, {} functions, {} s/run, {} runs/cell\n\n",
        base.cluster.workers,
        base.num_functions(),
        base.workload.duration_s,
        runs
    ));
    out.push_str(&format!(
        "{:<20} {:>4} {:>10} {:>8} {:>8} {:>8} {:>8} {:>7} {:>9} {:>8}\n",
        "scheduler", "VUs", "mean(ms)", "p90(ms)", "p95(ms)", "p99(ms)", "cold%", "CV", "completed", "rps"
    ));
    for &vus in vu_levels {
        for sched in schedulers {
            let (agg, _) = run_cell(base, sched, vus, runs)?;
            out.push_str(&format!(
                "{:<20} {:>4} {:>10.1} {:>8.1} {:>8.1} {:>8.1} {:>6.1}% {:>7.3} {:>9.0} {:>8.1}\n",
                sched,
                vus,
                agg.mean_latency_ms.mean(),
                agg.p90_ms.mean(),
                agg.p95_ms.mean(),
                agg.p99_ms.mean(),
                agg.cold_rate.mean() * 100.0,
                agg.mean_cv.mean(),
                agg.completed.mean(),
                agg.rps.mean(),
            ));
        }
        out.push('\n');
    }
    Ok(out)
}

/// Figs 4-6: trace characterization report.
pub fn trace_report(universe: usize, duration_s: f64, seed: u64) -> String {
    let tr = SyntheticTrace::generate(universe, duration_s, seed);
    let mut out = String::new();
    out.push_str(&format!(
        "# Azure-like synthetic trace: {} functions, {:.0} min, {} invocations\n\n",
        universe,
        duration_s / 60.0,
        tr.invocations.len()
    ));

    // Fig 4 — skewed popularity.
    out.push_str("## Fig 4 — skewed function popularity\n");
    out.push_str(&format!(
        "top  1% of functions -> {:>5.1}% of invocations (paper: 51.3%)\n",
        tr.top_share(0.01) * 100.0
    ));
    out.push_str(&format!(
        "top 10% of functions -> {:>5.1}% of invocations (paper: 92.3%)\n",
        tr.top_share(0.10) * 100.0
    ));
    out.push_str("cumulative share curve (fraction of functions -> share):\n");
    for (frac, share) in tr.popularity_curve(10) {
        out.push_str(&format!("  {:>5.1}% -> {:>5.1}%\n", frac * 100.0, share * 100.0));
    }

    // Fig 5 — heterogeneous performance.
    out.push_str("\n## Fig 5 — heterogeneous function performance (first 15 functions)\n");
    for (f, mean, std) in tr.exec_heterogeneity(15, seed) {
        out.push_str(&format!(
            "  fn {:>5}: exec {:>8.1} ms +/- {:>7.1} ms\n",
            f,
            mean * 1000.0,
            std * 1000.0
        ));
    }

    // Fig 6 — bursty invocations.
    let (per_min, max_ratio) = tr.interarrival_per_minute();
    out.push_str("\n## Fig 6 — bursty invocations (mean interarrival per minute, ms)\n  ");
    for (i, v) in per_min.iter().enumerate() {
        if v.is_finite() {
            out.push_str(&format!("{v:.1} "));
        }
        if i % 10 == 9 {
            out.push_str("\n  ");
        }
    }
    out.push_str(&format!(
        "\nmax minute-over-minute swing: {max_ratio:.1}x (paper: up to 13.5x)\n"
    ));
    out
}

/// Fig 10 — latency CDFs, one series per scheduler (points as text).
pub fn latency_cdf_report(base: &Config, schedulers: &[String], runs: u64, points: usize) -> Result<String, String> {
    let mut out = String::new();
    out.push_str("# Fig 10 — response latency CDF per scheduler\n");
    for sched in schedulers {
        let (_, mut all) = run_cell(base, sched, base.workload.vus, runs)?;
        // Pool latencies across runs for the CDF.
        let mut pooled = crate::stats::Samples::new();
        for m in &mut all {
            for &v in m.latency_ms.values() {
                pooled.push(v);
            }
        }
        out.push_str(&format!("\n## {sched}\n"));
        for (val, q) in pooled.cdf(points) {
            out.push_str(&format!("  {:>8.1} ms  p={:.3}\n", val, q));
        }
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Config {
        let mut cfg = Config::default();
        cfg.workload.duration_s = 10.0;
        cfg.workload.vus = 5;
        cfg
    }

    #[test]
    fn evaluation_report_renders() {
        let out = evaluation_report(&tiny(), &["hiku".into(), "random".into()], &[5], 2).unwrap();
        assert!(out.contains("hiku"));
        assert!(out.contains("random"));
        assert!(out.contains("cold%"));
    }

    #[test]
    fn trace_report_contains_paper_anchors() {
        let out = trace_report(2000, 300.0, 1);
        assert!(out.contains("Fig 4"));
        assert!(out.contains("paper: 51.3%"));
        assert!(out.contains("Fig 6"));
    }

    #[test]
    fn cdf_report_monotone_series() {
        let out = latency_cdf_report(&tiny(), &["hiku".into()], 1, 10).unwrap();
        assert!(out.matches(" p=").count() >= 10);
    }

    #[test]
    fn bad_scheduler_is_error() {
        assert!(evaluation_report(&tiny(), &["bogus".into()], &[5], 1).is_err());
    }
}
