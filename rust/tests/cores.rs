//! Slot-invariant suite for core-granular scheduling (DESIGN.md §11).
//!
//! The contracts pinned here:
//! - **cores=1 bit-identity**: `sim.cores_per_worker = 1` (the default)
//!   is byte-identical to the pre-slot engine for the whole scheduler
//!   registry × {push, pull} × 3 seeds × shards {1, 2, 4} — serial runs
//!   against the seed reference core, sharded runs against the merge of
//!   independent reference-engine partition runs (the same transitive
//!   chain tests/determinism.rs uses). Default summaries must not even
//!   contain the `slots` block.
//! - **Slot conservation**: driving the public `Cluster` API with random
//!   assign/complete/crash churn, `busy + free == cores` holds per
//!   worker after every operation, and the aggregate free-slot count
//!   equals the per-worker sum.
//! - **Slot exclusivity**: no core slot ever hosts two in-flight
//!   executions — every `StartInfo.slot` (immediate or queued start)
//!   lands on a slot the shadow model says is free.
//! - **Chaos with slots**: a full sim run with `cores_per_worker > 1`,
//!   fault injection, autoscaling and sharding stays bit-reproducible
//!   and conserves `arrivals == completed + rejected + failed + stolen`.

use hiku::config::{ClusterConfig, Config};
use hiku::platform::{AssignOutcome, Cluster, SandboxId};
use hiku::prop_assert;
use hiku::sim::run_once;
use hiku::util::prop::{check, PropConfig};

const SEEDS: [u64; 3] = [1, 2, 3];

#[cfg(feature = "ref-heap")]
fn cfg(sched: &str, mode: &str, shards: usize) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.workload.vus = 8;
    c.workload.duration_s = 10.0;
    c.cluster.workers = 6;
    c.sim.shards = shards;
    c.dispatch.mode = mode.into();
    // The tentpole's off-switch, spelled out: slot granularity and the
    // rebind window both at their defaults.
    c.sim.cores_per_worker = 1;
    c.dispatch.rebind_window_s = 0.0;
    c
}

#[cfg(feature = "ref-heap")]
fn assert_no_slot_surface(m: &mut hiku::metrics::RunMetrics, label: &str) {
    assert!(!m.slots_enabled, "{label}: slots must be off at cores = 1");
    assert_eq!(m.rebound, 0, "{label}: no rebinds without a rebind window");
    assert!(
        m.summary_json().get("slots").is_none(),
        "{label}: cores = 1 summary must not grow a slots block"
    );
}

/// cores=1 × ALL_SCHEDULERS × {push, pull} × 3 seeds, serial engine:
/// bit-identical to the seed reference core, and the summary JSON is
/// byte-for-byte free of slot-era keys.
#[cfg(feature = "ref-heap")]
#[test]
fn cores1_serial_is_bit_identical_to_reference() {
    use hiku::scheduler::ALL_SCHEDULERS;
    use hiku::sim::run_once_reference;
    for sched in ALL_SCHEDULERS {
        for mode in ["push", "pull"] {
            for seed in SEEDS {
                let c = cfg(sched, mode, 1);
                let label = format!("{sched}/{mode}/seed{seed}");
                let mut a = run_once(&c, seed).unwrap_or_else(|e| panic!("{label}: {e}"));
                let mut r = run_once_reference(&c, seed).unwrap();
                assert_eq!(
                    a.events_processed, r.events_processed,
                    "{label}: event counts diverged"
                );
                assert_eq!(
                    a.summary_json().to_string_compact(),
                    r.summary_json().to_string_compact(),
                    "{label}: summaries diverged from the reference engine"
                );
                assert_no_slot_surface(&mut a, &label);
            }
        }
    }
}

/// cores=1 × ALL_SCHEDULERS × {push, pull} × 3 seeds × shards {2, 4}:
/// the sharded engine still equals the merge, in shard order, of
/// independent reference-engine runs of its partitions — the slot
/// fields riding in the shard load digests must be inert at cores = 1.
#[cfg(feature = "ref-heap")]
#[test]
fn cores1_sharded_matches_partitioned_reference() {
    use hiku::metrics::RunMetrics;
    use hiku::scheduler::{make_scheduler, ALL_SCHEDULERS};
    use hiku::sim::shard::{partition_config, shard_seed};
    use hiku::sim::Simulation;
    use hiku::workload::loadgen::Workload;
    use hiku::workload::spec::FunctionRegistry;

    let run_partition = |base: &Config, seed: u64, s: usize, n: usize| -> RunMetrics {
        let pc = partition_config(base, s, n);
        let registry = FunctionRegistry::functionbench(pc.workload.copies);
        let workload = Workload::generate(&pc.workload, registry.len(), seed);
        let sched = make_scheduler(&pc.scheduler, pc.cluster.workers).expect("scheduler");
        Simulation::new(&pc, &registry, &workload, sched, shard_seed(seed, s))
            .with_vu_slice(s, n)
            .with_reference_core()
            .run()
    };
    for sched in ALL_SCHEDULERS {
        for mode in ["push", "pull"] {
            for &shards in &[2usize, 4] {
                for seed in SEEDS {
                    let c = cfg(sched, mode, shards);
                    let label = format!("{sched}/{mode}/shards{shards}/seed{seed}");
                    let mut a = run_once(&c, seed).unwrap_or_else(|e| panic!("{label}: {e}"));
                    let mut merged: Option<RunMetrics> = None;
                    for s in 0..shards {
                        let m = run_partition(&c, seed, s, shards);
                        match &mut merged {
                            None => merged = Some(m),
                            Some(acc) => acc.merge(&m),
                        }
                    }
                    let mut b = merged.unwrap();
                    assert_eq!(
                        a.summary_json().to_string_compact(),
                        b.summary_json().to_string_compact(),
                        "{label}: sharded run diverged from partitioned reference"
                    );
                    assert_no_slot_surface(&mut a, &label);
                }
            }
        }
    }
}

/// Shadow model for the slot property tests: per-worker slot occupancy
/// (`Some(request_id)` = in flight) plus the sandbox → (worker, slot)
/// map needed to free the right slot on completion.
struct Shadow {
    slots: Vec<Vec<Option<u64>>>,
    by_sandbox: Vec<(usize, SandboxId, u32, u64)>,
}

impl Shadow {
    fn new(workers: usize, cores: usize) -> Self {
        Self { slots: vec![vec![None; cores]; workers], by_sandbox: Vec::new() }
    }

    /// Occupy the slot a start landed on; errors on double-booking.
    fn start(&mut self, w: usize, info: &hiku::platform::StartInfo) -> Result<(), String> {
        let Some(slot) = info.slot else {
            return Err(format!("start on worker {w} carried no slot in slot mode"));
        };
        let cell = &mut self.slots[w][slot as usize];
        if let Some(prev) = *cell {
            return Err(format!(
                "slot exclusivity violated: worker {w} slot {slot} already runs request \
                 {prev}, now also {}",
                info.request_id
            ));
        }
        *cell = Some(info.request_id);
        self.by_sandbox.push((w, info.sandbox, slot, info.request_id));
        Ok(())
    }

    fn complete(&mut self, w: usize, sb: SandboxId) -> Result<u32, String> {
        let Some(pos) = self.by_sandbox.iter().position(|&(pw, ps, _, _)| pw == w && ps == sb)
        else {
            return Err(format!("completed sandbox {sb} unknown to the shadow on worker {w}"));
        };
        let (_, _, slot, _) = self.by_sandbox.swap_remove(pos);
        self.slots[w][slot as usize] = None;
        Ok(slot)
    }

    fn crash(&mut self, w: usize) {
        for cell in &mut self.slots[w] {
            *cell = None;
        }
        self.by_sandbox.retain(|&(pw, _, _, _)| pw != w);
    }

    fn busy(&self, w: usize) -> usize {
        self.slots[w].iter().filter(|s| s.is_some()).count()
    }
}

/// The conservation + exclusivity invariant after every operation:
/// `busy + free == cores` per worker, the aggregate equals the sum, and
/// the load index's per-worker view agrees with the shadow.
fn check_invariant(cluster: &Cluster, shadow: &Shadow, cores: usize) -> Result<(), String> {
    let mut sum_free = 0usize;
    for w in 0..cluster.active_workers() {
        let free = cluster.worker_free_slots(w);
        let busy = shadow.busy(w);
        prop_assert!(
            busy + free == cores,
            "conservation violated on worker {w}: busy {busy} + free {free} != cores {cores}"
        );
        // Ground truth straight off the worker's slot vector.
        let (flags, _) = cluster.worker(w).slot_state();
        let flagged = flags.iter().filter(|&&b| b).count();
        prop_assert!(
            flagged == busy,
            "worker {w} slot flags say {flagged} busy, shadow says {busy}"
        );
        sum_free += free;
    }
    prop_assert!(
        cluster.total_free_slots() == sum_free,
        "aggregate free slots {} != per-worker sum {sum_free}",
        cluster.total_free_slots()
    );
    Ok(())
}

/// Random assign/complete/crash churn against the public `Cluster` API:
/// slot conservation holds after **every** operation, crashes included
/// (a crash zeroes the worker's busy set and the aggregates follow).
#[test]
fn prop_slot_conservation_under_churn_and_crashes() {
    check("slot-conservation", PropConfig { cases: 90, ..Default::default() }, |rng, size| {
        let workers = 2 + rng.index(3);
        let cores = 2 + rng.index(3);
        let ccfg = ClusterConfig {
            workers,
            mem_mb: 4096,
            concurrency: cores,
            elastic: false,
            ..Default::default()
        };
        let mut cluster = Cluster::new_with_cores(&ccfg, cores);
        let mut shadow = Shadow::new(workers, cores);
        let mut rid = 0u64;
        let mut t = 0.0;
        for _ in 0..size * 4 {
            t += 0.2;
            match rng.index(8) {
                // Assign dominates so queues actually form.
                0..=4 => {
                    let w = rng.index(workers);
                    let f = rng.index(4);
                    // Exercise both the warm-affine default and explicit
                    // slot pins (the scheduler's AssignSlot path).
                    let preferred = if rng.index(3) == 0 {
                        Some(rng.index(cores) as u32)
                    } else {
                        None
                    };
                    rid += 1;
                    match cluster.assign_slot(w, rid, f, 256, t, preferred) {
                        AssignOutcome::Started(info) => shadow.start(w, &info)?,
                        AssignOutcome::Queued => {
                            prop_assert!(
                                shadow.busy(w) == cores,
                                "worker {w} queued a request with {} free slots",
                                cores - shadow.busy(w)
                            );
                        }
                    }
                }
                5 | 6 => {
                    // Complete a random in-flight execution; a queued
                    // request may start on the freed slot.
                    if shadow.by_sandbox.is_empty() {
                        continue;
                    }
                    let (w, sb, _, _) =
                        shadow.by_sandbox[rng.index(shadow.by_sandbox.len())];
                    let (_expiry, started) = cluster.complete(w, sb, t);
                    let freed = shadow.complete(w, sb)?;
                    if let Some(info) = started {
                        prop_assert!(
                            info.slot == Some(freed),
                            "queued start took slot {:?}, expected the freed slot {freed}",
                            info.slot
                        );
                        shadow.start(w, &info)?;
                    }
                }
                _ => {
                    // Crash: busy slots vanish, the queue drops, and the
                    // aggregates must stay exact (snapshot/journal sync).
                    let w = rng.index(workers);
                    let _ = cluster.crash(w);
                    shadow.crash(w);
                    prop_assert!(
                        cluster.worker_free_slots(w) == cores,
                        "crashed worker {w} reports {} free slots, want all {cores}",
                        cluster.worker_free_slots(w)
                    );
                }
            }
            check_invariant(&cluster, &shadow, cores)?;
        }
        Ok(())
    });
}

/// Warm-affinity agreement: `warm_free_slot` must name a slot that is
/// (a) free and (b) last ran the function — checked against the raw
/// slot vectors after every start/complete.
#[test]
fn prop_warm_free_slot_agrees_with_slot_state() {
    check("warm-free-slot", PropConfig { cases: 60, ..Default::default() }, |rng, size| {
        let cores = 2 + rng.index(3);
        let ccfg = ClusterConfig {
            workers: 2,
            mem_mb: 4096,
            concurrency: cores,
            elastic: false,
            ..Default::default()
        };
        let mut cluster = Cluster::new_with_cores(&ccfg, cores);
        let mut shadow = Shadow::new(2, cores);
        let mut rid = 0u64;
        let mut t = 0.0;
        for _ in 0..size * 3 {
            t += 0.3;
            let w = rng.index(2);
            if rng.index(2) == 0 || shadow.by_sandbox.is_empty() {
                let f = rng.index(3);
                rid += 1;
                if let AssignOutcome::Started(info) = cluster.assign_slot(w, rid, f, 256, t, None)
                {
                    shadow.start(w, &info)?;
                }
            } else {
                let (cw, sb, _, _) = shadow.by_sandbox[rng.index(shadow.by_sandbox.len())];
                let (_expiry, started) = cluster.complete(cw, sb, t);
                shadow.complete(cw, sb)?;
                if let Some(info) = started {
                    shadow.start(cw, &info)?;
                }
            }
            for wk in 0..2 {
                let (flags, last_fn) = cluster.worker(wk).slot_state();
                for f in 0..3 {
                    match cluster.warm_free_slot(wk, f) {
                        Some(s) => {
                            let s = s as usize;
                            prop_assert!(
                                !flags[s] && last_fn[s] == f,
                                "warm_free_slot({wk}, {f}) = {s} but busy={} last_fn={}",
                                flags[s],
                                last_fn[s]
                            );
                        }
                        None => {
                            let exists = (0..flags.len())
                                .any(|s| !flags[s] && last_fn[s] == f);
                            prop_assert!(
                                !exists,
                                "warm_free_slot({wk}, {f}) = None but a warm free slot exists"
                            );
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

/// Full-sim chaos with the slot model on: crashes, stragglers, reactive
/// autoscaling and sharding — bit-reproducible per (seed, shards), the
/// conservation identity holds, and the slots summary block appears.
#[test]
fn slot_mode_chaos_reproducible_and_conserving() {
    for &shards in &[1usize, 2] {
        for seed in SEEDS {
            let mut c = Config::default();
            c.scheduler.name = "hiku".into();
            c.workload.vus = 16;
            c.workload.duration_s = 20.0;
            c.cluster.workers = 6;
            c.cluster.elastic = false; // required by the slot model
            c.sim.shards = shards;
            c.sim.cores_per_worker = 2;
            c.dispatch.mode = "pull".into();
            c.autoscale.policy = "reactive".into();
            c.autoscale.max_workers = 10;
            c.faults.enabled = true;
            c.faults.crash_rate = 3.0;
            c.faults.mttr_s = 4.0;
            c.faults.straggler_frac = 0.2;
            c.faults.straggler_slowdown = 3.0;
            let label = format!("slot-chaos/shards{shards}/seed{seed}");
            let mut a = run_once(&c, seed).unwrap_or_else(|e| panic!("{label}: {e}"));
            let mut b = run_once(&c, seed).unwrap();
            assert_eq!(
                a.summary_json().to_string_compact(),
                b.summary_json().to_string_compact(),
                "{label}: chaos run not reproducible"
            );
            assert!(a.slots_enabled, "{label}: slots block must be on");
            assert_eq!(
                a.arrivals,
                a.completed + a.rejected + a.failed + a.stolen,
                "{label}: conservation violated (arrivals {} completed {} rejected {} \
                 failed {} stolen {})",
                a.arrivals,
                a.completed,
                a.rejected,
                a.failed,
                a.stolen
            );
            assert!(a.completed > 0, "{label}: the cluster must still serve requests");
            assert!(a.worker_crashes > 0, "{label}: the fault machinery must fire");
        }
    }
}

/// Push-mode rebind conserves too, and actually fires on a config built
/// to queue: more offered load than slots, a generous rebind window.
#[test]
fn rebind_conserves_and_meters() {
    let mut c = Config::default();
    c.scheduler.name = "random".into(); // eager binder, no load awareness
    c.workload.vus = 24;
    c.workload.duration_s = 15.0;
    c.cluster.workers = 4;
    c.cluster.elastic = false;
    c.sim.cores_per_worker = 2;
    c.dispatch.mode = "push".into();
    c.dispatch.rebind_window_s = 1.0;
    let mut a = run_once(&c, 1).expect("rebind run");
    let mut b = run_once(&c, 1).expect("rebind rerun");
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "rebind run not reproducible"
    );
    assert_eq!(
        a.arrivals,
        a.completed + a.rejected + a.failed + a.stolen,
        "rebind conservation violated"
    );
    assert!(
        a.rebound > 0,
        "random placement over 4x2 slots at 24 VUs must queue somewhere while \
         another worker idles — the rebind window never fired"
    );
    assert!(a.slots_enabled, "rebind window must enable the slots summary block");
}
