//! Integration tests for the autoscale subsystem: scale-event invariants
//! across every scheduler, determinism under closed-loop autoscaling, and
//! the policy-driven consolidation of the old scripted entry points.

use hiku::config::{Config, SchedulerConfig};
use hiku::prop_assert;
use hiku::scheduler::{make_scheduler, Hiku, SchedCtx, Scheduler, ALL_SCHEDULERS};
use hiku::sim::run_once;
use hiku::util::prop::{check, PropConfig};
use hiku::util::rng::Pcg64;

fn cfg(sched: &str, vus: usize, dur: f64) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.workload.vus = vus;
    c.workload.duration_s = dur;
    c
}

/// Property (satellite invariant): after `on_worker_removed`, no scheduler
/// ever selects the drained worker, across random warm-up histories of
/// selects/completions/evictions.
#[test]
fn prop_no_scheduler_selects_drained_worker() {
    for name in ALL_SCHEDULERS {
        check(
            &format!("drained-worker-{name}"),
            PropConfig { cases: 60, ..Default::default() },
            |rng, size| {
                let workers = 3 + rng.index(5);
                let scfg = SchedulerConfig { name: name.into(), ..Default::default() };
                let mut s = make_scheduler(&scfg, workers)?;
                let loads = vec![1u32; workers];
                // Random warm-up: routed requests, idle advertisements,
                // evictions — so internal state (rings, idle queues)
                // references every worker.
                for _ in 0..size * 3 {
                    let f = rng.index(6);
                    let w = {
                        let mut c = SchedCtx::new(&loads, rng);
                        s.select(f, &mut c)
                    };
                    prop_assert!(w < workers, "{name}: out-of-range {w}");
                    match rng.index(3) {
                        0 => {
                            let mut c = SchedCtx::new(&loads, rng);
                            s.on_complete(w, f, &mut c);
                        }
                        1 => s.on_evict(w, f),
                        _ => {}
                    }
                }
                // Drain the top 1-2 workers (LIFO, as the platform does).
                let drains = 1 + rng.index(usize::min(2, workers - 1));
                let active = workers - drains;
                for d in 0..drains {
                    s.on_worker_removed(workers - 1 - d);
                }
                let act_loads = vec![0u32; active];
                for f in 0..24 {
                    let w = {
                        let mut c = SchedCtx::new(&act_loads, rng);
                        s.select(f, &mut c)
                    };
                    prop_assert!(
                        w < active,
                        "{name}: selected drained worker {w} (active {active})"
                    );
                }
                Ok(())
            },
        );
    }
}

/// Satellite invariant: draining a worker purges every advertisement it
/// left in Hiku's idle queues (no stale pull targets).
#[test]
fn hiku_drain_purges_idle_queues() {
    let mut h = Hiku::new(4);
    let mut rng = Pcg64::new(9);
    let loads = [0u32; 4];
    for f in 0..6 {
        let mut c = SchedCtx::new(&loads, &mut rng);
        h.on_complete(3, f, &mut c);
        let mut c = SchedCtx::new(&loads, &mut rng);
        h.on_complete(1, f, &mut c);
    }
    assert_eq!(h.idle_entries(), 12);
    h.on_worker_removed(3);
    assert_eq!(h.idle_entries(), 6, "drained worker's advertisements must be purged");
    // Every remaining pull resolves to the surviving advertiser.
    let act_loads = [0u32; 3];
    for f in 0..6 {
        let mut c = SchedCtx::new(&act_loads, &mut rng);
        assert_eq!(h.select(f, &mut c), 1);
    }
    assert_eq!(h.idle_entries(), 0);
}

/// Property: after `on_worker_added` every scheduler still selects in
/// range and can reach the new worker through normal operation.
#[test]
fn prop_worker_added_stays_in_range() {
    for name in ALL_SCHEDULERS {
        check(
            &format!("worker-added-{name}"),
            PropConfig { cases: 40, ..Default::default() },
            |rng, size| {
                let workers = 2 + rng.index(4);
                let scfg = SchedulerConfig { name: name.into(), ..Default::default() };
                let mut s = make_scheduler(&scfg, workers)?;
                s.on_worker_added(workers);
                let grown = workers + 1;
                let loads = vec![0u32; grown];
                for _ in 0..size * 2 {
                    let f = rng.index(6);
                    let w = {
                        let mut c = SchedCtx::new(&loads, rng);
                        s.select(f, &mut c)
                    };
                    prop_assert!(w < grown, "{name}: out-of-range {w} after add");
                }
                Ok(())
            },
        );
    }
}

/// Determinism (acceptance criterion): with the closed-loop autoscaler
/// enabled, repeated runs under one seed are bit-identical.
#[test]
fn autoscale_deterministic_under_seed() {
    for policy in ["reactive", "predictive"] {
        let mut c = cfg("hiku", 60, 40.0);
        c.cluster.workers = 2;
        c.autoscale.policy = policy.into();
        c.autoscale.min_workers = 2;
        c.autoscale.max_workers = 8;
        c.autoscale.cooldown_s = 5.0;
        let a = run_once(&c, 31).unwrap();
        let b = run_once(&c, 31).unwrap();
        assert_eq!(a.completed, b.completed, "{policy}");
        assert_eq!(a.cold_starts, b.cold_starts, "{policy}");
        assert_eq!(a.scaling_timeline, b.scaling_timeline, "{policy}");
        let (mut a, mut b) = (a, b);
        assert!(a.mean_latency_ms() == b.mean_latency_ms(), "{policy}: latency diverged");
    }
}

/// The reactive policy must actually add capacity when a small cluster is
/// saturated — and the accounting must see it.
#[test]
fn reactive_scales_up_under_load() {
    let mut c = cfg("hiku", 100, 60.0);
    c.cluster.workers = 2;
    c.autoscale.policy = "reactive".into();
    c.autoscale.min_workers = 2;
    c.autoscale.max_workers = 8;
    c.autoscale.cooldown_s = 5.0;
    let m = run_once(&c, 32).unwrap();
    assert_eq!(m.issued, m.completed);
    let peak = m.scaling_timeline.iter().map(|&(_, a)| a).max().unwrap();
    assert!(peak > 2, "100 VUs on 2 workers must trigger scale-up (peak {peak})");
    assert!(m.scale_event_count() >= 1);
    assert!(
        m.worker_seconds > 2.0 * 60.0,
        "worker-seconds {} must exceed the static-2-worker floor",
        m.worker_seconds
    );
}

/// Consolidation check (the `run_scaled`/`run_scale_events` shims are
/// gone): the `scheduled` policy configured through `[autoscale]`
/// replays the parsed event list verbatim at its exact times, and
/// alternate spec spellings of the same event list are bit-identical
/// runs.
#[test]
fn scheduled_policy_replays_parsed_events() {
    use hiku::autoscale::{AutoscalePolicy, Scheduled};
    let s = Scheduled::parse("30;60").unwrap();
    assert_eq!(s.scheduled_events(), vec![(30.0, true), (60.0, true)]);

    let mut c = cfg("hiku", 60, 90.0);
    c.cluster.workers = 3;
    c.autoscale.policy = "scheduled".into();
    c.autoscale.events = "30;60".into();
    let a = run_once(&c, 22).unwrap();
    let mut c2 = c.clone();
    c2.autoscale.events = " +30, 60.0 ".into();
    let b = run_once(&c2, 22).unwrap();
    assert_eq!(a.completed, b.completed);
    assert_eq!(a.cold_starts, b.cold_starts);
    assert_eq!(a.scaling_timeline, b.scaling_timeline);
    // The events applied at their exact scripted times: 3 -> 4 -> 5.
    assert!(a.scaling_timeline.contains(&(30.0, 4)));
    assert!(a.scaling_timeline.contains(&(60.0, 5)));
    let (mut a, mut b) = (a, b);
    assert!(a.mean_latency_ms() == b.mean_latency_ms());
}

/// The predictive policy's pools actually speculate, and speculation pays:
/// some pre-warmed sandboxes serve warm starts.
#[test]
fn predictive_prewarm_pools_speculate_and_hit() {
    // Pin the worker count (min == max == workers) so the comparison
    // isolates pre-warming from scaling.
    let mk = |policy: &str| {
        let mut c = cfg("hiku", 40, 60.0);
        c.cluster.workers = 5;
        c.autoscale.policy = policy.into();
        c.autoscale.min_workers = 5;
        c.autoscale.max_workers = 5;
        c
    };
    let none = run_once(&mk("none"), 33).unwrap();
    let pred = run_once(&mk("predictive"), 33).unwrap();
    assert_eq!(none.prewarm_spawned, 0);
    assert!(pred.prewarm_spawned > 0, "predictive must speculate");
    assert!(pred.prewarm_hits > 0, "some speculation must pay off");
    assert!(
        pred.cold_rate() <= none.cold_rate(),
        "pre-warming must not increase the cold rate: {} vs {}",
        pred.cold_rate(),
        none.cold_rate()
    );
}

/// Open-loop burst scenario (acceptance criterion): predictive beats
/// reactive on cold starts without a runaway worker-seconds bill.
#[test]
fn predictive_beats_reactive_on_cold_starts_for_bursts() {
    use hiku::report::bursty_trace;
    use hiku::sim::run_trace;
    let mut base = cfg("hiku", 1, 120.0);
    base.cluster.workers = 2;
    base.autoscale.min_workers = 2;
    base.autoscale.max_workers = 10;
    let trace = bursty_trace(base.num_functions(), base.workload.duration_s, 77);
    let run = |policy: &str| {
        let mut c = base.clone();
        c.autoscale.policy = policy.into();
        run_trace(&c, &trace, 77).unwrap()
    };
    let reactive = run("reactive");
    let predictive = run("predictive");
    assert!(
        predictive.cold_rate() < reactive.cold_rate(),
        "predictive {} must beat reactive {} on cold rate",
        predictive.cold_rate(),
        reactive.cold_rate()
    );
    assert!(
        predictive.worker_seconds < 2.0 * reactive.worker_seconds.max(1.0),
        "predictive worker-seconds {} vs reactive {} (not comparable)",
        predictive.worker_seconds,
        reactive.worker_seconds
    );
}
