//! Telemetry-layer integration tests (ISSUE 6 acceptance):
//! - sketch-mode percentiles within 1% of exact mode on a 100k-request run
//!   (the DDSketch alpha = 0.005 guarantee, observed end to end);
//! - merged shard sketches/timelines match a single pooled collector;
//! - telemetry outputs (sketch summaries, lifecycle traces) are
//!   bit-reproducible for a fixed (seed, shards);
//! - phase profiling surfaces sane fractions in `summary_json`.

use hiku::config::{Config, TelemetryConfig};
use hiku::metrics::RunMetrics;
use hiku::report::export::{chrome_trace_json, trace_csv};
use hiku::sim::{run_once, run_trace};
use hiku::util::rng::Pcg64;
use hiku::workload::loadgen::OpenLoopTrace;

/// Deterministic open-loop trace: `n` arrivals uniformly spaced over
/// `duration_s`, round-robin over `functions` types.
fn uniform_trace(n: usize, duration_s: f64, functions: usize) -> OpenLoopTrace {
    let dt = duration_s / n as f64;
    let arr: Vec<(f64, usize)> = (0..n).map(|i| (i as f64 * dt, i % functions)).collect();
    OpenLoopTrace::from_synthetic(&arr, functions)
}

#[test]
fn sketch_percentiles_within_one_percent_of_exact_on_100k_requests() {
    let mut cfg = Config::default();
    cfg.cluster.workers = 1_000;
    cfg.workload.duration_s = 30.0;
    let trace = uniform_trace(100_500, 30.0, 40);
    let mut exact = run_trace(&cfg, &trace, 42).expect("exact run");
    cfg.telemetry.sketch = true;
    let mut sketch = run_trace(&cfg, &trace, 42).expect("sketch run");
    assert!(exact.completed >= 100_000, "need a 100k-request run, got {}", exact.completed);
    assert_eq!(
        exact.completed, sketch.completed,
        "metric storage mode must not change the simulation"
    );
    for p in [50.0, 99.0] {
        let e = exact.latency_percentile_ms(p);
        let s = sketch.latency_percentile_ms(p);
        assert!(e.is_finite() && e > 0.0, "degenerate exact p{p}: {e}");
        assert!(
            (s - e).abs() <= 0.01 * e,
            "p{p} relative error over 1%: exact {e:.3} ms vs sketch {s:.3} ms"
        );
    }
    // Sketch mode marks itself in the summary; exact mode stays silent.
    assert!(sketch.summary_json().get("sketch").is_some());
    assert!(exact.summary_json().get("sketch").is_none());
}

#[test]
fn merged_collectors_match_one_pooled_collector() {
    // Property: for a stream split across shard-local collectors, the
    // shard-merge reduction reproduces a single collector fed the pooled
    // stream — percentiles bit-identical (count arithmetic in both
    // storage modes), throughput step-sums exact.
    for sketch in [false, true] {
        let tel = TelemetryConfig { sketch, ..Default::default() };
        let mut pooled = RunMetrics::with_telemetry("hiku", 4, 4, 10.0, &tel);
        let mut parts: Vec<RunMetrics> =
            (0..4).map(|_| RunMetrics::with_telemetry("hiku", 1, 1, 10.0, &tel)).collect();
        let mut rng = Pcg64::new(99);
        for i in 0..20_000u64 {
            let lat_s = rng.next_f64().powi(3) * 2.0; // heavy-tailed
            let cold = i % 7 == 0;
            let t = (i % 10) as f64;
            pooled.record_response(lat_s, cold, 0.0, t);
            let k = rng.index(4);
            parts[k].record_response(lat_s, cold, 0.0, t);
        }
        let mut merged = parts.remove(0);
        for p in &parts {
            merged.merge(p);
        }
        assert_eq!(merged.completed, pooled.completed);
        for q in [50.0, 90.0, 99.0] {
            assert_eq!(
                merged.latency_percentile_ms(q),
                pooled.latency_percentile_ms(q),
                "sketch={sketch} p{q} diverged after merge"
            );
        }
        assert!((merged.mean_latency_ms() - pooled.mean_latency_ms()).abs() < 1e-6);
        let (mc, pc) = (merged.throughput.cumulative(), pooled.throughput.cumulative());
        assert_eq!(mc.last(), pc.last(), "sketch={sketch} throughput step-sum diverged");
    }
}

#[test]
fn sharded_sketch_and_trace_outputs_are_bit_reproducible() {
    let mut cfg = Config::default();
    cfg.cluster.workers = 8;
    cfg.workload.vus = 24;
    cfg.workload.duration_s = 20.0;
    cfg.sim.shards = 2;
    cfg.dispatch.mode = "pull".into();
    cfg.telemetry.sketch = true;
    cfg.telemetry.trace_sample = 4;
    cfg.validate().expect("valid telemetry config");
    let mut a = run_once(&cfg, 7).expect("run a");
    let mut b = run_once(&cfg, 7).expect("run b");
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "sketch summary must be bit-reproducible per (seed, shards)"
    );
    assert_eq!(trace_csv(&a), trace_csv(&b), "trace.csv must be bit-reproducible");
    assert_eq!(
        chrome_trace_json(&a).to_string_compact(),
        chrome_trace_json(&b).to_string_compact()
    );
    assert!(!a.trace.is_empty(), "sampling 1 in 4 requests must record spans");
    assert!(a.summary_json().get("trace_spans").is_some());
    // Arrival spans exist for sampled requests and phases come from the
    // documented taxonomy.
    let taxonomy =
        ["arrival", "decide", "pending", "bind", "cold_init", "service", "complete"];
    assert!(a.trace.spans().iter().any(|s| s.phase == "arrival"));
    for s in a.trace.spans() {
        assert!(taxonomy.contains(&s.phase), "unknown span phase {}", s.phase);
        assert!(s.end_s >= s.start_s, "negative span {}..{}", s.start_s, s.end_s);
        assert!(s.shard < 2, "shard tag out of range");
    }
}

#[test]
fn tracing_and_profiling_leave_the_run_bit_identical() {
    // Telemetry must be write-only: the same (config, seed) with tracing
    // and phase profiling enabled reproduces the plain run's metrics
    // exactly (summaries compare equal once the gated telemetry keys are
    // ignored — easiest checked field by field on the scalars).
    let mut cfg = Config::default();
    cfg.cluster.workers = 6;
    cfg.workload.vus = 20;
    cfg.workload.duration_s = 15.0;
    cfg.dispatch.mode = "pull".into();
    let mut plain = run_once(&cfg, 3).expect("plain run");
    cfg.telemetry.trace_sample = 2;
    cfg.telemetry.phase_profile = true;
    let mut traced = run_once(&cfg, 3).expect("traced run");
    assert_eq!(plain.completed, traced.completed);
    assert_eq!(plain.events_processed, traced.events_processed);
    assert_eq!(plain.enqueued, traced.enqueued);
    assert_eq!(plain.mean_latency_ms(), traced.mean_latency_ms());
    assert_eq!(plain.latency_percentile_ms(99.0), traced.latency_percentile_ms(99.0));
    // And the profile itself is sane: fractions in [0, 1] of positive wall.
    let j = traced.summary_json();
    let ph = j.get("phases").expect("phases object in profiled summary");
    assert!(ph.get("wall_s").unwrap().as_f64().unwrap() > 0.0);
    for k in
        ["pop_frac", "decide_frac", "barrier_frac", "handoff_frac", "autoscale_frac"]
    {
        let v = ph.get(k).unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&v), "{k} = {v} out of range");
    }
    assert!(plain.summary_json().get("phases").is_none(), "profile keys must be gated");
}
