//! Dispatch-protocol integration suite (DESIGN.md §8).
//!
//! Push-mode bit-identity against the pre-redesign engine lives in
//! `tests/determinism.rs` (`push_mode_decision_api_is_bit_identical`);
//! this file covers the pull protocol's behavioral contracts:
//!
//! - conservation: every arrival is bound-and-completed or metered as a
//!   reject — nothing is silently dropped;
//! - drained-worker safety: a parked request is never bound to a worker
//!   outside the active set, under arbitrary autoscale churn (the bind
//!   path enforces this with a hard assert, so the property run fails
//!   loudly on any violation);
//! - per-function admission: `dispatch.queue_cap`/`queue_caps` isolate
//!   rejects to the overflowing function — a hot function's backlog
//!   never costs a background function admission;
//! - fairness: deficit-round-robin draining bounds a starved function's
//!   pending wait strictly below the arrival-order FIFO baseline on a
//!   hot-function monopoly trace (cross-shard steal donation);
//! - cost-aware waiting: adaptive per-function deadlines
//!   (`dispatch.adaptive_wait`) cut the mean pending wait against a
//!   large global `max_wait_s` on an overloaded cluster;
//! - scale-to-zero: `autoscale.min_workers = 0` parks the cluster, a
//!   queue-triggered wake restores `⌈backlog/concurrency⌉` workers at
//!   once (a 100-request burst never serializes behind one worker), the
//!   first request after idle pays its cold start, and worker-seconds
//!   beat the min=1 run;
//! - the headline scenario: pull dispatch does not cold-start more than
//!   push on the bursty workload (the full comparison table is
//!   `cargo bench --bench ablation_dispatch`);
//! - sharded pull runs are bit-reproducible and actually hand off tasks
//!   across shards at epoch barriers;
//! - head-of-line blocking (DESIGN.md §11): core-granular pull
//!   (`sim.cores_per_worker > 1`, late binding through the pending
//!   queue) keeps the short class's p99 arrival→start wait strictly
//!   below worker-granular pull on the mixed short/long trace, and the
//!   conservation identity survives the slot model.

use hiku::config::Config;
use hiku::prop_assert;
use hiku::report::{bursty_trace, mixed_class_trace, monopoly_trace};
use hiku::sim::{run_once, run_trace};
use hiku::util::prop::{check, PropConfig};
use hiku::workload::loadgen::OpenLoopTrace;

fn pull_cfg(sched: &str, vus: usize, dur: f64) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.workload.vus = vus;
    c.workload.duration_s = dur;
    c.dispatch.mode = "pull".into();
    c
}

#[test]
fn pull_mode_conserves_and_parks() {
    // Few function types + many VUs per worker => executions of the same
    // function overlap, so the enqueue path genuinely fires.
    let mut c = pull_cfg("hiku", 30, 30.0);
    c.workload.copies = 1; // 8 function types
    for seed in [1u64, 2, 3] {
        let m = run_once(&c, seed).unwrap();
        assert_eq!(m.issued, m.completed, "closed loop must drain (seed {seed})");
        assert_eq!(m.rejected, 0, "unbounded queue never rejects");
        assert_eq!(m.cold_starts + m.warm_starts, m.completed);
        assert!(m.enqueued > 0, "pull mode never parked a request (seed {seed})");
        assert_eq!(
            m.pending_wait_ms.seen(),
            m.enqueued,
            "every parked request must bind exactly once"
        );
        assert!(m.peak_pending >= 1);
        assert!(!m.pending_timeline.is_empty(), "pull mode samples the pending depth");
        assert_eq!(
            m.pending_timeline.last().map(|&(_, d)| d),
            Some(0),
            "the queue must drain by the end of the run"
        );
    }
}

#[test]
fn pull_mode_is_deterministic() {
    let mut c = pull_cfg("hiku", 20, 25.0);
    c.workload.copies = 1;
    let mut a = run_once(&c, 7).unwrap();
    let mut b = run_once(&c, 7).unwrap();
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "pull runs must be bit-reproducible under a fixed seed"
    );
}

/// Property: under aggressive reactive churn (short cooldown, wide
/// bounds) the pull protocol conserves every request and never binds a
/// parked one to a drained worker — `Simulation::bind_pending` enforces
/// the latter with a hard assert, so a violation panics the case.
#[test]
fn prop_pull_never_binds_drained_workers() {
    check("pull-vs-drain", PropConfig { cases: 20, ..Default::default() }, |rng, _size| {
        let mut c = pull_cfg("hiku", 8 + rng.index(16), 12.0 + rng.next_f64() * 8.0);
        c.workload.copies = 1;
        c.cluster.workers = 2 + rng.index(4);
        c.dispatch.max_wait_s = 0.1 + rng.next_f64();
        c.dispatch.fair = rng.index(2) == 0; // both drain orders safe
        c.autoscale.policy = "reactive".into();
        c.autoscale.min_workers = 1;
        c.autoscale.max_workers = c.cluster.workers + 3;
        c.autoscale.cooldown_s = 0.5;
        c.autoscale.scale_up_util = 0.9;
        c.autoscale.scale_down_util = 0.4;
        let seed = rng.next_u64();
        let m = run_once(&c, seed).map_err(|e| format!("run failed: {e}"))?;
        prop_assert!(
            m.issued == m.completed,
            "issued {} != completed {} (seed {})",
            m.issued,
            m.completed,
            seed
        );
        prop_assert!(
            m.cold_starts + m.warm_starts == m.completed,
            "start accounting leaked (seed {})",
            seed
        );
        Ok(())
    });
}

#[test]
fn per_function_caps_isolate_rejects_to_the_hot_function() {
    // Hot chameleon at 30/s overloads 2 workers (~20/s capacity), so
    // its pending queue sits pinned at the 4-slot per-function cap; the
    // background dd pairs park a line of at most 2 (the 0.5 s deadline
    // drains each pair before the next arrives) and must NEVER be the
    // ones rejected — the admission isolation per-function caps exist
    // for.
    let trace = monopoly_trace(30.0, 30.0, false);
    let mut c = pull_cfg("hiku", 1, 30.0);
    c.cluster.workers = 2;
    c.dispatch.queue_cap = 4;
    c.dispatch.max_wait_s = 0.5;
    c.dispatch.adaptive_wait = false;
    let mut m = run_trace(&c, &trace, 3).unwrap();
    assert!(m.rejected > 0, "a 4-slot per-function cap must reject the 30/s hot stream");
    assert_eq!(
        m.reject_count_fn(0),
        m.rejected,
        "every reject must belong to the hot function"
    );
    assert_eq!(m.reject_count_fn(1), 0, "the background function must never reject");
    assert!(m.reject_rate() > 0.0);
    assert_eq!(m.issued, m.completed, "every admitted request still completes");
    assert!(
        m.latency_percentile_ms(99.0).is_finite(),
        "rejects must not poison the latency percentiles"
    );
    assert!(
        m.pending_wait_p99_fn_ms(1) > 0.0,
        "the background function parked and must report a per-function wait"
    );
    let j = m.summary_json();
    assert_eq!(j.get("rejected").unwrap().as_u64(), Some(m.rejected));
    assert!(j.get("reject_rate").unwrap().as_f64().unwrap() > 0.0);
    let by_fn = j.get("rejects_by_fn").unwrap().as_arr().unwrap();
    assert_eq!(by_fn.len(), 1, "exactly one function rejects: {by_fn:?}");
}

#[test]
fn fair_drr_bounds_starved_function_wait_vs_fifo() {
    // The ISSUE's fairness property: on a hot-function monopoly trace,
    // the starved background function's p99 pending wait under DRR
    // draining is strictly better than under the PR 4 arrival-order
    // FIFO. The lever is cross-shard steal donation: the donor shard's
    // backlog is almost all hot requests, so FIFO donations hand off the
    // hot head while the background waits out its deadline against the
    // drowned worker; DRR gives the background queue a share of every
    // handoff, landing it on the idle shard within an epoch.
    let dur = 25.0;
    let trace = monopoly_trace(24.0, dur, true);
    let run = |fair: bool| {
        let mut c = pull_cfg("hiku", 1, dur);
        c.cluster.workers = 3;
        c.sim.shards = 2;
        c.sim.barrier_s = 0.25;
        c.dispatch.max_wait_s = 1.0;
        c.dispatch.adaptive_wait = false; // isolate the drain-order axis
        c.dispatch.queue_cap = 10;
        c.dispatch.steal_batch = 2;
        c.dispatch.fair = fair;
        run_trace(&c, &trace, 5).unwrap()
    };
    let mut fair = run(true);
    let mut fifo = run(false);
    for (label, m) in [("fair", &fair), ("fifo", &fifo)] {
        assert_eq!(m.issued, m.completed, "{label}: conservation");
        assert!(m.stolen > 0, "{label}: the donor shard never handed off a task");
        assert_eq!(m.reject_count_fn(1), 0, "{label}: background must never reject");
        assert_eq!(
            m.rejected,
            m.reject_count_fn(0),
            "{label}: only the hot function may reject"
        );
    }
    let bg_fair = fair.pending_wait_p99_fn_ms(1);
    let bg_fifo = fifo.pending_wait_p99_fn_ms(1);
    assert!(bg_fair > 0.0 && bg_fifo > 0.0, "background must actually park in both runs");
    assert!(
        bg_fair < bg_fifo,
        "DRR must bound the starved function's p99 wait strictly below FIFO: \
         fair {bg_fair:.1} ms vs fifo {bg_fifo:.1} ms"
    );
}

#[test]
fn adaptive_deadlines_cut_waits_on_overload() {
    // Cost-aware waiting: with a deliberately huge global max_wait_s,
    // the fixed-deadline run makes overloaded-function requests wait out
    // the full 3 s; the adaptive run caps each function's deadline at
    // its observed cold−warm delta (~0.14 s for chameleon), so the mean
    // pending wait collapses while nothing is lost.
    let trace = monopoly_trace(30.0, 25.0, false);
    let mut fixed = pull_cfg("hiku", 1, 25.0);
    fixed.cluster.workers = 2;
    fixed.dispatch.max_wait_s = 3.0;
    fixed.dispatch.adaptive_wait = false;
    let mut adaptive = fixed.clone();
    adaptive.dispatch.adaptive_wait = true;
    let a = run_trace(&adaptive, &trace, 2).unwrap();
    let f = run_trace(&fixed, &trace, 2).unwrap();
    assert_eq!(a.issued, a.completed);
    assert_eq!(f.issued, f.completed);
    assert!(a.enqueued > 0 && f.enqueued > 0);
    assert!(
        a.mean_pending_wait_ms() < f.mean_pending_wait_ms(),
        "adaptive deadlines must cut the mean pending wait: adaptive {:.1} ms vs fixed {:.1} ms",
        a.mean_pending_wait_ms(),
        f.mean_pending_wait_ms()
    );
}

#[test]
fn scale_to_zero_parks_wakes_and_saves_cost() {
    // A short burst, a long idle gap, one straggler arrival: the
    // reactive policy drains the cluster to zero during the gap, the
    // straggler parks and wakes one worker (⌈1/concurrency⌉ = 1), and
    // its start is cold (the drain reclaimed every sandbox).
    let mut arr: Vec<(f64, usize)> = (0..20).map(|i| (0.5 + i as f64 * 0.1, i % 8)).collect();
    arr.push((25.0, 0));
    let trace = OpenLoopTrace::from_synthetic(&arr, 40);
    let mut c = pull_cfg("hiku", 1, 30.0);
    c.cluster.workers = 2;
    c.autoscale.policy = "reactive".into();
    c.autoscale.min_workers = 0;
    c.autoscale.max_workers = 4;
    c.autoscale.cooldown_s = 2.0;
    let m = run_trace(&c, &trace, 7).unwrap();
    assert_eq!(m.completed, 21, "every arrival must resolve, including the post-idle one");
    assert_eq!(m.issued, m.completed);
    assert!(
        m.scaling_timeline.iter().any(|&(_, w)| w == 0),
        "cluster never parked to zero: {:?}",
        m.scaling_timeline
    );
    assert!(m.cold_starts >= 1, "the wake's first request must pay a cold start");
    // Cost: parking to zero must beat holding the min=1 floor.
    let mut floor1 = c.clone();
    floor1.autoscale.min_workers = 1;
    let m1 = run_trace(&floor1, &trace, 7).unwrap();
    assert!(
        m.worker_seconds < m1.worker_seconds,
        "scale-to-zero saved nothing: {} vs {}",
        m.worker_seconds,
        m1.worker_seconds
    );
}

#[test]
fn wake_batching_restores_workers_proportional_to_backlog() {
    // Regression for the single-wake bug: a 100-request burst into an
    // empty (min_workers = 0) cluster used to wake exactly one worker
    // and serialize the whole backlog behind it. The batched wake
    // restores ⌈backlog / concurrency⌉ workers (bounded by max_workers)
    // before flushing, so the burst spreads immediately.
    let mut arr: Vec<(f64, usize)> = Vec::new();
    for i in 0..100 {
        arr.push((20.0, i % 8)); // one same-timestamp burst after idle
    }
    let trace = OpenLoopTrace::from_synthetic(&arr, 40);
    let mut c = pull_cfg("hiku", 1, 40.0);
    c.cluster.workers = 2;
    c.autoscale.policy = "reactive".into();
    c.autoscale.min_workers = 0;
    c.autoscale.max_workers = 8;
    c.autoscale.cooldown_s = 2.0;
    let mut batched = run_trace(&c, &trace, 11).unwrap();
    assert_eq!(batched.completed, 100);
    assert_eq!(batched.issued, batched.completed);
    assert!(
        batched.scaling_timeline.iter().any(|&(_, w)| w == 0),
        "cluster never parked to zero: {:?}",
        batched.scaling_timeline
    );
    let peak = batched.scaling_timeline.iter().map(|&(_, w)| w).max().unwrap();
    assert!(
        peak > 1,
        "a 100-request burst must wake more than one worker (peak {peak}): {:?}",
        batched.scaling_timeline
    );
    // Single-wake baseline: capping the pool at one worker is exactly
    // the old behavior — the batched wake must drain the burst faster.
    let mut capped = c.clone();
    capped.autoscale.max_workers = 1;
    let mut single = run_trace(&capped, &trace, 11).unwrap();
    assert_eq!(single.completed, 100);
    assert!(
        batched.latency_percentile_ms(95.0) < single.latency_percentile_ms(95.0),
        "batched wake must beat the single-wake tail: {:.0} ms vs {:.0} ms",
        batched.latency_percentile_ms(95.0),
        single.latency_percentile_ms(95.0)
    );
}

#[test]
fn pull_does_not_cold_start_more_than_push_on_bursty_workload() {
    // The headline scenario (quantified by benches/ablation_dispatch.rs):
    // letting a request wait briefly for a warm worker instead of
    // forcing an immediate fallback placement. Deterministic per seed,
    // so this is a stable regression guard, not a statistical claim.
    // `adaptive_wait` is pinned off so the comparison isolates the base
    // protocol (adaptive deadlines are covered by
    // `adaptive_deadlines_cut_waits_on_overload`).
    let trace = bursty_trace(40, 60.0, 42);
    let mut push = pull_cfg("hiku", 1, 60.0);
    push.dispatch.mode = "push".into();
    let mut pull = push.clone();
    pull.dispatch.mode = "pull".into();
    pull.dispatch.adaptive_wait = false;
    for seed in [1u64, 2] {
        let a = run_trace(&push, &trace, seed).unwrap();
        let b = run_trace(&pull, &trace, seed).unwrap();
        assert!(b.enqueued > 0, "pull must actually park requests (seed {seed})");
        assert!(
            b.cold_rate() <= a.cold_rate(),
            "pull increased the cold-start fraction: push {:.4} vs pull {:.4} (seed {seed})",
            a.cold_rate(),
            b.cold_rate()
        );
        assert_eq!(b.issued, b.completed);
    }
}

#[test]
fn sharded_pull_steals_at_barriers_and_reproduces() {
    // Constructed imbalance: worker split over 2 shards is 2 + 1; the
    // even-indexed (shard 0) arrivals are a light, cheap stream while
    // the odd-indexed (shard 1) arrivals hammer one function at ~16/s —
    // beyond a single 4-core worker's capacity for chameleon (~392 ms
    // warm), so shard 1 parks continuously while shard 0 idles. The
    // coordinator must hand tasks across at the epoch barriers.
    let mut arr: Vec<(f64, usize)> = Vec::new();
    for k in 0..240 {
        let t = 0.05 + k as f64 * 0.0625; // both streams span 0.05..15.05 s
        arr.push((t, 5)); // even index -> shard 0, linpack (58 ms warm)
        arr.push((t, 0)); // odd index -> shard 1, chameleon (392 ms warm)
    }
    let trace = OpenLoopTrace::from_synthetic(&arr, 40);
    let mut c = pull_cfg("hiku", 1, 20.0);
    c.cluster.workers = 3;
    c.sim.shards = 2;
    c.dispatch.max_wait_s = 1.0; // parked requests span a whole epoch
    c.dispatch.adaptive_wait = false;
    let mut a = run_trace(&c, &trace, 5).unwrap();
    let mut b = run_trace(&c, &trace, 5).unwrap();
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "sharded pull runs must be bit-reproducible"
    );
    assert_eq!(a.issued, a.completed, "handoffs must not lose requests");
    assert_eq!(a.completed, 480);
    assert!(a.enqueued > 0);
    assert!(a.stolen > 0, "the overloaded shard never handed off a task");
    // Stealing is off in push mode: same setup, no handoffs, and the
    // partition-closed contract still conserves everything.
    let mut p = c.clone();
    p.dispatch.mode = "push".into();
    let mp = run_trace(&p, &trace, 5).unwrap();
    assert_eq!(mp.stolen, 0);
    assert_eq!(mp.issued, mp.completed);
}

/// The slot model's headline regression (DESIGN.md §11, `cargo bench
/// --bench ablation_cores` for the full table): on the mixed short/long
/// trace, core-granular pull must cut the short class's p99
/// arrival→start wait strictly below worker-granular pull.
///
/// Both arms are least-connections (the baselines' `decide` always
/// binds, so the contrast is purely the slot model) over 4 workers × 4
/// execution slots. Worker-granular: a trailing short binds eagerly and
/// queues in some worker's FIFO behind burst-overflow longs, waiting
/// multiple long service times. Core-granular: the scheduler sees zero
/// free slots cluster-wide, the engine parks the short instead (late
/// binding), and the first completion anywhere claims it via
/// `claim_stale_pending` — one partial long service time.
#[test]
fn core_granular_pull_beats_worker_granular_on_short_p99() {
    let dur = 20.0;
    let trace = mixed_class_trace(dur);
    let base = || {
        let mut c = pull_cfg("least-connections", 1, dur);
        c.cluster.workers = 4;
        c.cluster.concurrency = 4;
        c.cluster.elastic = false;
        c
    };
    let mut worker_granular = base();
    worker_granular.sim.cores_per_worker = 1;
    let mut core_granular = base();
    core_granular.sim.cores_per_worker = 4;
    let mut a = run_trace(&worker_granular, &trace, 1).expect("worker-granular run");
    let mut b = run_trace(&core_granular, &trace, 1).expect("core-granular run");
    let (p99_worker, p99_cores) = (a.hol_wait_p99_ms(true), b.hol_wait_p99_ms(true));
    assert!(a.completed > 0 && b.completed > 0, "both arms must serve the trace");
    assert!(
        p99_worker > 0.0,
        "worker-granular must actually queue shorts behind longs (p99 {p99_worker} ms)"
    );
    assert!(
        p99_cores < p99_worker,
        "core-granular pull must beat worker-granular on short p99 wait: \
         {p99_cores:.1} ms vs {p99_worker:.1} ms"
    );
    assert!(!a.slots_enabled, "cores = 1 must not enable the slots block");
    assert!(b.slots_enabled, "cores = 4 must enable the slots block");
}

/// The conservation identity (`arrivals == completed + rejected +
/// failed + stolen`) holds with the slot model on, for both dispatch
/// modes — late binding parks and the rebind window re-routes, but
/// every arrival still resolves exactly once.
#[test]
fn slot_mode_conserves_arrivals() {
    for (mode, rebind) in [("pull", 0.0), ("push", 0.5)] {
        let mut c = pull_cfg("least-connections", 20, 15.0);
        c.cluster.workers = 4;
        c.cluster.elastic = false;
        c.sim.cores_per_worker = 4;
        c.dispatch.mode = mode.into();
        c.dispatch.rebind_window_s = rebind;
        let m = run_once(&c, 2).expect("slot-mode run");
        assert_eq!(
            m.arrivals,
            m.completed + m.rejected + m.failed + m.stolen,
            "{mode}: slot-mode conservation violated (arrivals {} completed {} \
             rejected {} failed {} stolen {})",
            m.arrivals,
            m.completed,
            m.rejected,
            m.failed,
            m.stolen
        );
        assert!(m.completed > 0, "{mode}: the cluster must serve requests");
    }
}
