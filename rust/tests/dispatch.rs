//! Dispatch-protocol integration suite (DESIGN.md §8).
//!
//! Push-mode bit-identity against the pre-redesign engine lives in
//! `tests/determinism.rs` (`push_mode_decision_api_is_bit_identical`);
//! this file covers the pull protocol's behavioral contracts:
//!
//! - conservation: every arrival is bound-and-completed or metered as a
//!   reject — nothing is silently dropped;
//! - drained-worker safety: a parked request is never bound to a worker
//!   outside the active set, under arbitrary autoscale churn (the bind
//!   path enforces this with a hard assert, so the property run fails
//!   loudly on any violation);
//! - admission: `dispatch.queue_cap` rejects surface in the metrics and
//!   never contaminate the latency percentiles;
//! - scale-to-zero: `autoscale.min_workers = 0` parks the cluster, a
//!   queue-triggered wake restores capacity, the first request after
//!   idle pays its cold start, and worker-seconds beat the min=1 run;
//! - the headline scenario: pull dispatch does not cold-start more than
//!   push on the bursty workload (the full comparison table is
//!   `cargo bench --bench ablation_dispatch`);
//! - sharded pull runs are bit-reproducible and actually hand off tasks
//!   across shards at epoch barriers.

use hiku::config::Config;
use hiku::prop_assert;
use hiku::report::bursty_trace;
use hiku::sim::{run_once, run_trace};
use hiku::util::prop::{check, PropConfig};
use hiku::workload::loadgen::OpenLoopTrace;

fn pull_cfg(sched: &str, vus: usize, dur: f64) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.workload.vus = vus;
    c.workload.duration_s = dur;
    c.dispatch.mode = "pull".into();
    c
}

#[test]
fn pull_mode_conserves_and_parks() {
    // Few function types + many VUs per worker => executions of the same
    // function overlap, so the enqueue path genuinely fires.
    let mut c = pull_cfg("hiku", 30, 30.0);
    c.workload.copies = 1; // 8 function types
    for seed in [1u64, 2, 3] {
        let m = run_once(&c, seed).unwrap();
        assert_eq!(m.issued, m.completed, "closed loop must drain (seed {seed})");
        assert_eq!(m.rejected, 0, "unbounded queue never rejects");
        assert_eq!(m.cold_starts + m.warm_starts, m.completed);
        assert!(m.enqueued > 0, "pull mode never parked a request (seed {seed})");
        assert_eq!(
            m.pending_wait_ms.seen(),
            m.enqueued,
            "every parked request must bind exactly once"
        );
        assert!(m.peak_pending >= 1);
        assert!(!m.pending_timeline.is_empty(), "pull mode samples the pending depth");
        assert_eq!(
            m.pending_timeline.last().map(|&(_, d)| d),
            Some(0),
            "the queue must drain by the end of the run"
        );
    }
}

#[test]
fn pull_mode_is_deterministic() {
    let mut c = pull_cfg("hiku", 20, 25.0);
    c.workload.copies = 1;
    let mut a = run_once(&c, 7).unwrap();
    let mut b = run_once(&c, 7).unwrap();
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "pull runs must be bit-reproducible under a fixed seed"
    );
}

/// Property: under aggressive reactive churn (short cooldown, wide
/// bounds) the pull protocol conserves every request and never binds a
/// parked one to a drained worker — `Simulation::bind_pending` enforces
/// the latter with a hard assert, so a violation panics the case.
#[test]
fn prop_pull_never_binds_drained_workers() {
    check("pull-vs-drain", PropConfig { cases: 20, ..Default::default() }, |rng, _size| {
        let mut c = pull_cfg("hiku", 8 + rng.index(16), 12.0 + rng.next_f64() * 8.0);
        c.workload.copies = 1;
        c.cluster.workers = 2 + rng.index(4);
        c.dispatch.max_wait_s = 0.1 + rng.next_f64();
        c.autoscale.policy = "reactive".into();
        c.autoscale.min_workers = 1;
        c.autoscale.max_workers = c.cluster.workers + 3;
        c.autoscale.cooldown_s = 0.5;
        c.autoscale.scale_up_util = 0.9;
        c.autoscale.scale_down_util = 0.4;
        let seed = rng.next_u64();
        let m = run_once(&c, seed).map_err(|e| format!("run failed: {e}"))?;
        prop_assert!(
            m.issued == m.completed,
            "issued {} != completed {} (seed {})",
            m.issued,
            m.completed,
            seed
        );
        prop_assert!(
            m.cold_starts + m.warm_starts == m.completed,
            "start accounting leaked (seed {})",
            seed
        );
        Ok(())
    });
}

#[test]
fn queue_cap_rejects_are_metered_not_swallowed() {
    let trace = bursty_trace(40, 30.0, 9);
    let mut c = pull_cfg("hiku", 1, 30.0);
    c.cluster.workers = 2;
    c.dispatch.queue_cap = 4;
    c.dispatch.max_wait_s = 5.0; // long waits keep the tiny queue full
    let mut m = run_trace(&c, &trace, 3).unwrap();
    assert!(m.rejected > 0, "a 4-slot queue must reject under 40 req/s bursts");
    assert!(m.reject_rate() > 0.0);
    assert_eq!(m.issued, m.completed, "every admitted request still completes");
    assert!(
        m.latency_percentile_ms(99.0).is_finite(),
        "rejects must not poison the latency percentiles"
    );
    let j = m.summary_json();
    assert_eq!(j.get("rejected").unwrap().as_u64(), Some(m.rejected));
    assert!(j.get("reject_rate").unwrap().as_f64().unwrap() > 0.0);
}

#[test]
fn scale_to_zero_parks_wakes_and_saves_cost() {
    // A short burst, a long idle gap, one straggler arrival: the
    // reactive policy drains the cluster to zero during the gap, the
    // straggler parks and wakes one worker, and its start is cold (the
    // drain reclaimed every sandbox).
    let mut arr: Vec<(f64, usize)> = (0..20).map(|i| (0.5 + i as f64 * 0.1, i % 8)).collect();
    arr.push((25.0, 0));
    let trace = OpenLoopTrace::from_synthetic(&arr, 40);
    let mut c = pull_cfg("hiku", 1, 30.0);
    c.cluster.workers = 2;
    c.autoscale.policy = "reactive".into();
    c.autoscale.min_workers = 0;
    c.autoscale.max_workers = 4;
    c.autoscale.cooldown_s = 2.0;
    let m = run_trace(&c, &trace, 7).unwrap();
    assert_eq!(m.completed, 21, "every arrival must resolve, including the post-idle one");
    assert_eq!(m.issued, m.completed);
    assert!(
        m.scaling_timeline.iter().any(|&(_, w)| w == 0),
        "cluster never parked to zero: {:?}",
        m.scaling_timeline
    );
    assert!(m.cold_starts >= 1, "the wake's first request must pay a cold start");
    // Cost: parking to zero must beat holding the min=1 floor.
    let mut floor1 = c.clone();
    floor1.autoscale.min_workers = 1;
    let m1 = run_trace(&floor1, &trace, 7).unwrap();
    assert!(
        m.worker_seconds < m1.worker_seconds,
        "scale-to-zero saved nothing: {} vs {}",
        m.worker_seconds,
        m1.worker_seconds
    );
}

#[test]
fn pull_does_not_cold_start_more_than_push_on_bursty_workload() {
    // The headline scenario (quantified by benches/ablation_dispatch.rs):
    // letting a request wait briefly for a warm worker instead of
    // forcing an immediate fallback placement. Deterministic per seed,
    // so this is a stable regression guard, not a statistical claim.
    let trace = bursty_trace(40, 60.0, 42);
    let mut push = pull_cfg("hiku", 1, 60.0);
    push.dispatch.mode = "push".into();
    let mut pull = push.clone();
    pull.dispatch.mode = "pull".into();
    for seed in [1u64, 2] {
        let a = run_trace(&push, &trace, seed).unwrap();
        let b = run_trace(&pull, &trace, seed).unwrap();
        assert!(b.enqueued > 0, "pull must actually park requests (seed {seed})");
        assert!(
            b.cold_rate() <= a.cold_rate(),
            "pull increased the cold-start fraction: push {:.4} vs pull {:.4} (seed {seed})",
            a.cold_rate(),
            b.cold_rate()
        );
        assert_eq!(b.issued, b.completed);
    }
}

#[test]
fn sharded_pull_steals_at_barriers_and_reproduces() {
    // Constructed imbalance: worker split over 2 shards is 2 + 1; the
    // even-indexed (shard 0) arrivals are a light, cheap stream while
    // the odd-indexed (shard 1) arrivals hammer one function at ~16/s —
    // beyond a single 4-core worker's capacity for chameleon (~392 ms
    // warm), so shard 1 parks continuously while shard 0 idles. The
    // coordinator must hand tasks across at the epoch barriers.
    let mut arr: Vec<(f64, usize)> = Vec::new();
    for k in 0..240 {
        let t = 0.05 + k as f64 * 0.0625; // both streams span 0.05..15.05 s
        arr.push((t, 5)); // even index -> shard 0, linpack (58 ms warm)
        arr.push((t, 0)); // odd index -> shard 1, chameleon (392 ms warm)
    }
    let trace = OpenLoopTrace::from_synthetic(&arr, 40);
    let mut c = pull_cfg("hiku", 1, 20.0);
    c.cluster.workers = 3;
    c.sim.shards = 2;
    c.dispatch.max_wait_s = 1.0; // parked requests span a whole epoch
    let mut a = run_trace(&c, &trace, 5).unwrap();
    let mut b = run_trace(&c, &trace, 5).unwrap();
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "sharded pull runs must be bit-reproducible"
    );
    assert_eq!(a.issued, a.completed, "handoffs must not lose requests");
    assert_eq!(a.completed, 480);
    assert!(a.enqueued > 0);
    assert!(a.stolen > 0, "the overloaded shard never handed off a task");
    // Stealing is off in push mode: same setup, no handoffs, and the
    // partition-closed contract still conserves everything.
    let mut p = c.clone();
    p.dispatch.mode = "push".into();
    let mp = run_trace(&p, &trace, 5).unwrap();
    assert_eq!(mp.stolen, 0);
    assert_eq!(mp.issued, mp.completed);
}
