//! Chaos suite for the fault-injection & recovery subsystem (DESIGN.md
//! §10): determinism under churn + crashes, the conservation identity,
//! the pull-vs-push outage contrast, warm-state migration, and the
//! adaptive wait floor.
//!
//! The contracts pinned here:
//! - **Determinism**: for a fixed (seed, shards) a chaos run — random
//!   crash/recover churn, stragglers, cold-init failures, reactive
//!   autoscaling, cross-shard stealing — is bit-reproducible.
//! - **Conservation**: every admitted request resolves exactly once:
//!   `arrivals == completed + rejected + failed + stolen` (a stolen
//!   request is counted at both its donor and its recipient, and the
//!   donor's copy resolves as the donation).
//! - **Recovery beats address-based push**: on a mid-run kill, pull-mode
//!   hiku fails strictly fewer requests than push-mode hash-mod, which
//!   keeps re-hashing onto the dead worker until budgets burn out.
//! - **Zero-overhead off switch**: `faults.enabled = false` (default)
//!   schedules nothing and meters nothing (byte-identity to the
//!   pre-fault engine is enforced by tests/determinism.rs against the
//!   reference core, which has no fault path at all).

use hiku::config::Config;
use hiku::metrics::RunMetrics;
use hiku::sim::run_once;

const SEEDS: [u64; 3] = [1, 2, 3];

fn chaos_cfg(shards: usize) -> Config {
    let mut c = Config::default();
    c.scheduler.name = "hiku".into();
    c.workload.vus = 24;
    c.workload.duration_s = 25.0;
    c.cluster.workers = 6;
    c.sim.shards = shards;
    c.dispatch.mode = "pull".into();
    // Reactive churn so the active boundary moves while workers die.
    c.autoscale.policy = "reactive".into();
    c.autoscale.max_workers = 10;
    c.autoscale.cooldown_s = 2.0;
    // The whole fault surface at once.
    c.faults.enabled = true;
    // Per worker per minute: ~1.7 expected kills per worker over 25 s,
    // so every (seed, shards) combo sees crashes with near-certainty.
    c.faults.crash_rate = 4.0;
    c.faults.mttr_s = 4.0;
    c.faults.straggler_frac = 0.25;
    c.faults.straggler_slowdown = 4.0;
    c.faults.init_fail_prob = 0.02;
    c
}

/// The conservation identity over a (possibly merged) run: every arrival
/// resolves exactly once. `stolen` appears because a cross-shard handoff
/// counts the request at both ends — the donor's copy resolves as the
/// donation, the recipient's as completed/failed.
fn assert_conserved(m: &RunMetrics, label: &str) {
    assert_eq!(
        m.arrivals,
        m.completed + m.rejected + m.failed + m.stolen,
        "{label}: conservation violated (arrivals {} != completed {} + rejected {} + \
         failed {} + stolen {})",
        m.arrivals,
        m.completed,
        m.rejected,
        m.failed,
        m.stolen
    );
}

#[test]
fn chaos_runs_reproducible_and_conserving() {
    // shards 1/2/4 × 3 seeds: bit-reproducible summaries, conservation
    // green, and the fault machinery actually firing.
    for &shards in &[1usize, 2, 4] {
        for seed in SEEDS {
            let c = chaos_cfg(shards);
            let mut a = run_once(&c, seed).expect("chaos run");
            let mut b = run_once(&c, seed).expect("chaos rerun");
            assert_eq!(
                a.summary_json().to_string_compact(),
                b.summary_json().to_string_compact(),
                "chaos run diverged (shards {shards}, seed {seed})"
            );
            assert_conserved(&a, &format!("shards{shards}/seed{seed}"));
            assert!(
                a.worker_crashes > 0,
                "crash_rate 1.0/min over 25 s x 6 workers must kill someone \
                 (shards {shards}, seed {seed})"
            );
            assert!(a.completed > 0, "the cluster must still serve requests");
        }
    }
}

#[test]
fn faults_off_meters_nothing() {
    let mut c = Config::default();
    c.workload.vus = 10;
    c.workload.duration_s = 10.0;
    assert!(!c.faults.enabled, "faults must default off");
    let m = run_once(&c, 1).expect("baseline run");
    assert!(!m.faults_enabled);
    assert_eq!(
        (m.worker_crashes, m.failed, m.retried, m.hedged, m.re_routed, m.migrated),
        (0, 0, 0, 0, 0, 0),
        "a faults-off run must not meter any fault activity"
    );
    // `arrivals` is maintained regardless — the identity holds trivially.
    assert_conserved(&m, "faults-off");
}

#[test]
fn pull_hiku_fails_less_than_push_hash_on_mid_run_kill() {
    // Kill worker 1 at t=6 for 10 s. Push-mode hash-mod keeps hashing
    // arrivals onto the corpse until their retry budgets burn out; the
    // pull router observes liveness, re-routes the binds, and should
    // fail strictly fewer requests.
    let mut failed_pull = 0u64;
    let mut failed_hash = 0u64;
    let mut retried_pull = 0u64;
    for seed in SEEDS {
        let mut mk = |sched: &str, mode: &str| -> RunMetrics {
            let mut c = Config::default();
            c.scheduler.name = sched.into();
            c.dispatch.mode = mode.into();
            c.workload.vus = 20;
            c.workload.duration_s = 20.0;
            c.faults.enabled = true;
            c.faults.crashes = "6:1".into();
            c.faults.mttr_s = 10.0;
            let m = run_once(&c, seed).expect("kill run");
            assert_conserved(&m, &format!("{sched}/{mode}/seed{seed}"));
            assert_eq!(m.worker_crashes, 1, "{sched}: the explicit kill must fire");
            assert_eq!(m.worker_recoveries, 1, "{sched}: the recovery must fire");
            m
        };
        let pull = mk("hiku", "pull");
        let hash = mk("hash-mod", "push");
        failed_pull += pull.failed;
        failed_hash += hash.failed;
        retried_pull += pull.retried;
    }
    assert!(retried_pull > 0, "in-flight work on the corpse must be retried");
    assert!(
        failed_pull < failed_hash,
        "pull-mode hiku must fail strictly fewer than push-mode hash-mod \
         ({failed_pull} vs {failed_hash})"
    );
    assert!(failed_hash > 0, "hash-mod must actually lose requests to the dead worker");
}

#[test]
fn warm_state_migrates_with_retried_requests() {
    // Killing a worker banks its idle warm inventory (within keep-alive);
    // a *retried* request whose new worker holds no idle sandbox of its
    // function consumes a banked entry as an instant pre-warm — metered
    // as `migrated`. Two staggered kills on a small hot cluster make
    // bank-hit opportunities plentiful; summed over seeds so a single
    // unlucky sandbox layout cannot flake the assertion.
    let mut migrated = 0u64;
    let mut retried = 0u64;
    let mut bank_spawned = 0u64;
    let mut bank_hits = 0u64;
    let mut cold_starts = 0u64;
    for seed in [1u64, 2, 3, 4] {
        let mut c = Config::default();
        c.scheduler.name = "hiku".into();
        c.dispatch.mode = "pull".into();
        c.workload.vus = 24;
        c.workload.duration_s = 20.0;
        c.cluster.workers = 3;
        c.faults.enabled = true;
        c.faults.crashes = "8:0;10:1".into();
        c.faults.mttr_s = 6.0;
        assert!(!c.cluster.prewarm, "the prewarm policy must stay off so every \
             prewarm counter below belongs to the migration bank");
        let m = run_once(&c, seed).expect("migration run");
        assert_conserved(&m, &format!("migration/seed{seed}"));
        migrated += m.migrated;
        retried += m.retried;
        bank_spawned += m.prewarm_spawned;
        bank_hits += m.prewarm_hits;
        cold_starts += m.cold_starts;
    }
    assert!(retried > 0, "the kills must displace in-flight work");
    assert!(
        migrated > 0,
        "across 4 seeds, at least one retried request must inherit a \
         harvested warm sandbox (migrated = 0, retried = {retried})"
    );
    // The cold-start delta, pinned exactly: with the prewarm policy off,
    // every prewarm in these runs is a bank migration, and each migrated
    // request's start consumes it warm on first use — i.e. migration
    // really skipped that request's cold init rather than just metering
    // an event.
    assert_eq!(
        bank_spawned, migrated,
        "every migration is exactly one banked prewarm (spawned {bank_spawned}, \
         migrated {migrated})"
    );
    assert_eq!(
        bank_hits, migrated,
        "every migrated request must start warm on its banked sandbox — a miss \
         means the retry paid the cold init migration claims to skip"
    );
    assert!(
        cold_starts > 0,
        "the kills must still force cold starts elsewhere, or the delta is vacuous"
    );
}

/// Conservation is a counter identity, not a sample identity — it must
/// hold bit-for-bit even when the latency/wait distributions are stored
/// as quantile sketches (`telemetry.sketch = true`), whose summaries
/// are approximate.
#[test]
fn chaos_conserves_in_sketch_mode() {
    for &shards in &[1usize, 2] {
        let mut c = chaos_cfg(shards);
        c.telemetry.sketch = true;
        for seed in SEEDS {
            let mut a = run_once(&c, seed).expect("sketch chaos run");
            let mut b = run_once(&c, seed).expect("sketch chaos rerun");
            assert_eq!(
                a.summary_json().to_string_compact(),
                b.summary_json().to_string_compact(),
                "sketch-mode chaos diverged (shards {shards}, seed {seed})"
            );
            assert_conserved(&a, &format!("sketch/shards{shards}/seed{seed}"));
            assert!(
                a.summary_json().get("sketch").is_some(),
                "sketch mode must stamp the summary"
            );
            assert!(a.completed > 0 && a.worker_crashes > 0);
        }
    }
}

#[test]
fn min_wait_floor_pins_adaptive_deadlines() {
    // With the floor raised to the cap, the adaptive deadline
    // `min(max_wait_s, penalty).max(min_wait_s)` is constantly
    // `max_wait_s` — so an adaptive run must be bit-identical to a
    // non-adaptive one. (This is exactly the satellite's guarantee: the
    // EWMA can never collapse the wait below the floor.)
    for seed in SEEDS {
        let mut base = Config::default();
        base.scheduler.name = "hiku".into();
        base.dispatch.mode = "pull".into();
        base.workload.vus = 16;
        base.workload.duration_s = 15.0;
        base.dispatch.max_wait_s = 0.5;

        let mut floored = base.clone();
        floored.dispatch.adaptive_wait = true;
        floored.dispatch.min_wait_s = 0.5;

        let mut fixed = base.clone();
        fixed.dispatch.adaptive_wait = false;

        let mut a = run_once(&floored, seed).expect("floored adaptive run");
        let mut b = run_once(&fixed, seed).expect("fixed-wait run");
        assert_eq!(
            a.summary_json().to_string_compact(),
            b.summary_json().to_string_compact(),
            "min_wait_s == max_wait_s must pin adaptive deadlines to the cap (seed {seed})"
        );
    }
}

#[test]
fn recovery_latency_is_metered() {
    let mut c = Config::default();
    c.workload.vus = 8;
    c.workload.duration_s = 15.0;
    c.faults.enabled = true;
    c.faults.crashes = "5:0".into();
    c.faults.mttr_s = 3.0;
    let mut m = run_once(&c, 2).expect("recovery run");
    assert_eq!(m.worker_crashes, 1);
    assert_eq!(m.worker_recoveries, 1);
    assert!(!m.recovery_latency_ms.is_empty());
    let down = m.recovery_latency_ms.percentile(50.0);
    assert!(
        (down - 3000.0).abs() < 1.0,
        "explicit-schedule recovery must take exactly mttr_s (got {down} ms)"
    );
}
