//! Determinism equivalence suite for the event-core overhaul and the
//! sharded parallel engine.
//!
//! The calendar-queue event core plus the incremental load/warm-supply
//! accounting must be *bit-identical* to the seed implementation (binary
//! heap + full-cluster scans), which lives on behind the `ref-heap`
//! feature as `Simulation::with_reference_core`. For every scheduler ×
//! {elastic, queue} × autoscale policy combination we run the same
//! (config, seed) on both engines and require identical `summary_json()`
//! output, event counts, and peak queue depth across ≥3 seeds.
//!
//! The sharded engine (`sim.shards > 1`, DESIGN.md §6) adds three more
//! contracts, pinned below:
//! - `--shards 1` never enters the parallel driver, so the serial path
//!   stays bit-identical to the reference engine;
//! - on partition-closed workloads a sharded run equals the merge of N
//!   independent *reference-engine* runs of its partitions;
//! - with the full barrier protocol active (policy ticks, power-of-d
//!   pre-warm placement messages) runs are bit-reproducible under
//!   (seed, shards) regardless of thread scheduling;
//! - batch-coalesced completions are state-identical to one-at-a-time
//!   dispatch (property test over the public `Cluster` API).

#![cfg(feature = "ref-heap")]

use hiku::config::{ClusterConfig, Config};
use hiku::metrics::RunMetrics;
use hiku::platform::{AssignOutcome, BatchCompletion, Cluster, SandboxId};
use hiku::prop_assert;
use hiku::report::monopoly_trace;
use hiku::scheduler::{make_scheduler, ALL_SCHEDULERS, COMPOSITE_SCHEDULERS, PAPER_SCHEDULERS};
use hiku::sim::shard::{partition_config, shard_seed};
use hiku::sim::{run_once, run_once_reference, run_trace, run_trace_reference, Simulation};
use hiku::util::json::Json;
use hiku::util::prop::{check, PropConfig};
use hiku::workload::azure::SyntheticTrace;
use hiku::workload::loadgen::{OpenLoopTrace, Workload};
use hiku::workload::spec::FunctionRegistry;

const SEEDS: [u64; 3] = [1, 2, 3];

fn cfg(sched: &str, vus: usize, dur: f64) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.workload.vus = vus;
    c.workload.duration_s = dur;
    c
}

fn assert_equiv_metrics(a: &mut RunMetrics, b: &mut RunMetrics, label: &str) {
    assert_eq!(
        a.events_processed, b.events_processed,
        "{label}: event counts diverged (calendar {} vs ref {})",
        a.events_processed, b.events_processed
    );
    assert_eq!(
        a.peak_event_queue, b.peak_event_queue,
        "{label}: peak queue depth diverged"
    );
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "{label}: summaries diverged"
    );
}

fn assert_equiv(c: &Config, seed: u64, label: &str) {
    let mut a = run_once(c, seed).unwrap_or_else(|e| panic!("{label}: {e}"));
    let mut b = run_once_reference(c, seed).unwrap_or_else(|e| panic!("{label}: {e}"));
    assert_equiv_metrics(&mut a, &mut b, &format!("{label}/seed{seed}"));
}

#[test]
fn all_schedulers_elastic_static() {
    // Composite (hiku+fallback) registry names ride along so the
    // ablation configs are regression-guarded too.
    for sched in ALL_SCHEDULERS.iter().chain(COMPOSITE_SCHEDULERS.iter()) {
        for seed in SEEDS {
            assert_equiv(&cfg(sched, 10, 20.0), seed, sched);
        }
    }
}

#[test]
fn push_mode_decision_api_is_bit_identical() {
    // The dispatch redesign's acceptance contract: an explicit
    // `dispatch.mode = "push"` routes every scheduler through the
    // Decision push adapter and must be bit-identical to both the
    // default config and the pre-redesign reference engine, for the
    // whole registry (composites included).
    for sched in ALL_SCHEDULERS.iter().chain(COMPOSITE_SCHEDULERS.iter()) {
        for seed in SEEDS {
            let base = cfg(sched, 10, 20.0);
            assert!(!base.pull_dispatch(), "push must stay the default dispatch mode");
            let mut push = base.clone();
            push.dispatch.mode = "push".into();
            let mut a = run_once(&push, seed).unwrap_or_else(|e| panic!("{sched}: {e}"));
            let mut b = run_once(&base, seed).unwrap();
            let mut r = run_once_reference(&push, seed).unwrap();
            assert_equiv_metrics(&mut a, &mut b, &format!("{sched}/push-vs-default/seed{seed}"));
            assert_equiv_metrics(&mut a, &mut r, &format!("{sched}/push-vs-reference/seed{seed}"));
        }
    }
}

#[test]
fn paper_schedulers_queue_mode() {
    // Hard admission queues (elastic=false) exercise the queued-start path
    // and the total_queued aggregate.
    for sched in PAPER_SCHEDULERS {
        for seed in SEEDS {
            let mut c = cfg(sched, 10, 20.0);
            c.cluster.elastic = false;
            assert_equiv(&c, seed, &format!("{sched}/queue"));
        }
    }
}

#[test]
fn autoscale_policies_equivalent() {
    // Scale events churn the active set, which the incremental aggregates
    // must track exactly (contributions move in/out at the boundary).
    for sched in ["hiku", "least-connections", "ch-bl"] {
        for policy in ["scheduled", "reactive", "predictive"] {
            for seed in SEEDS {
                let mut c = cfg(sched, 12, 25.0);
                c.autoscale.policy = policy.into();
                c.autoscale.max_workers = 9;
                c.autoscale.cooldown_s = 3.0;
                if policy == "scheduled" {
                    c.autoscale.events = "4,8,-15,-18".into();
                }
                assert_equiv(&c, seed, &format!("{sched}/{policy}"));
            }
        }
    }
}

#[test]
fn prewarm_heuristic_equivalent() {
    // cluster.prewarm drives on_prewarm_tick (warm-supply reads) and
    // spawn_prewarm (min-load-fitting placement) every simulated second.
    for sched in ["hiku", "random"] {
        for seed in SEEDS {
            let mut c = cfg(sched, 10, 20.0);
            c.cluster.prewarm = true;
            assert_equiv(&c, seed, &format!("{sched}/prewarm"));
        }
    }
}

#[test]
fn multi_instance_equivalent() {
    // Several scheduler instances = several independent load views, each
    // with its own min-load index.
    for seed in SEEDS {
        let mut c = cfg("hiku", 12, 20.0);
        c.scheduler.instances = 3;
        assert_equiv(&c, seed, "hiku/instances=3");
    }
}

// (The old `hiku_fallback_variants_equivalent` test folded into
// `all_schedulers_elastic_static`, which now chains COMPOSITE_SCHEDULERS
// through the identical engine-vs-reference check.)

#[test]
fn open_loop_trace_equivalent() {
    let c = cfg("hiku", 1, 60.0);
    let gen = SyntheticTrace::generate(40, 60.0, 777);
    let trace = OpenLoopTrace::from_synthetic(&gen.invocations, 40);
    for seed in SEEDS {
        let mut a = run_trace(&c, &trace, seed).expect("trace run");
        let mut b = run_trace_reference(&c, &trace, seed).expect("trace ref run");
        assert_equiv_metrics(&mut a, &mut b, &format!("open-loop/seed{seed}"));
    }
}

#[test]
fn repeated_runs_identical_on_new_core() {
    // The new core is also self-deterministic (not just ref-equivalent).
    let c = cfg("hiku", 10, 20.0);
    let mut a = run_once(&c, 7).unwrap();
    let mut b = run_once(&c, 7).unwrap();
    assert_eq!(a.summary_json().to_string_compact(), b.summary_json().to_string_compact());
}

// ---- sharded engine (sim.shards > 1) ----------------------------------

/// Serial reference run of one shard's partition on the seed (`ref-heap`)
/// engine: the shard's worker slice, its VU slice, its RNG seed — built
/// through the same public APIs the sharded driver uses internally.
fn run_partition_reference(base: &Config, seed: u64, s: usize, n: usize) -> RunMetrics {
    let pc = partition_config(base, s, n);
    let registry = FunctionRegistry::functionbench(pc.workload.copies);
    let workload = Workload::generate(&pc.workload, registry.len(), seed);
    let sched = make_scheduler(&pc.scheduler, pc.cluster.workers).expect("scheduler");
    Simulation::new(&pc, &registry, &workload, sched, shard_seed(seed, s))
        .with_vu_slice(s, n)
        .with_reference_core()
        .run()
}

#[test]
fn shards_one_is_the_serial_engine() {
    // The acceptance contract: --shards 1 is bit-identical to the PR 2
    // engine (and, transitively, to the seed reference engine).
    for seed in SEEDS {
        let c1 = cfg("hiku", 10, 20.0); // default shards = 1
        let mut c2 = cfg("hiku", 10, 20.0);
        c2.sim.shards = 1;
        let mut a = run_once(&c1, seed).unwrap();
        let mut b = run_once(&c2, seed).unwrap();
        let mut r = run_once_reference(&c2, seed).unwrap();
        assert_equiv_metrics(&mut a, &mut b, &format!("explicit-shards1/seed{seed}"));
        assert_equiv_metrics(&mut b, &mut r, &format!("shards1-vs-reference/seed{seed}"));
    }
}

#[test]
fn sharded_matches_partitioned_reference() {
    // Partition-closed workloads (static cluster, no pre-warm): a
    // parallel sharded run must equal the merge, in shard order, of N
    // independent serial runs of its partitions — run here on the
    // *reference* engine, which transitively pins the sharded engine all
    // the way back to the seed event core. Composite registry names ride
    // along (the push adapter covers them too).
    for sched in ALL_SCHEDULERS.iter().chain(COMPOSITE_SCHEDULERS.iter()) {
        for &shards in &[2usize, 4] {
            for seed in SEEDS {
                let mut c = cfg(sched, 12, 20.0);
                c.cluster.workers = 6;
                c.sim.shards = shards;
                let mut a = run_once(&c, seed).unwrap_or_else(|e| panic!("{sched}: {e}"));
                let mut merged: Option<RunMetrics> = None;
                for s in 0..shards {
                    let m = run_partition_reference(&c, seed, s, shards);
                    match &mut merged {
                        None => merged = Some(m),
                        Some(acc) => acc.merge(&m),
                    }
                }
                let mut b = merged.unwrap();
                assert_equiv_metrics(
                    &mut a,
                    &mut b,
                    &format!("{sched}/shards{shards}/seed{seed}"),
                );
            }
        }
    }
}

#[test]
fn sharded_open_loop_matches_partitioned_reference() {
    let mut c = cfg("hiku", 1, 40.0);
    c.cluster.workers = 6;
    c.sim.shards = 2;
    let gen = SyntheticTrace::generate(40, 40.0, 555);
    let trace = OpenLoopTrace::from_synthetic(&gen.invocations, 40);
    for seed in SEEDS {
        let mut a = run_trace(&c, &trace, seed).expect("sharded trace run");
        let mut merged: Option<RunMetrics> = None;
        for s in 0..2 {
            let pc = partition_config(&c, s, 2);
            let registry = FunctionRegistry::functionbench(pc.workload.copies);
            let mut wcfg = pc.workload.clone();
            wcfg.vus = 1; // open loop ignores the VU scripts
            let workload = Workload::generate(&wcfg, registry.len(), seed);
            let sched = make_scheduler(&pc.scheduler, pc.cluster.workers).expect("scheduler");
            let m = Simulation::new(&pc, &registry, &workload, sched, shard_seed(seed, s))
                .with_vu_slice(s, 2)
                .with_reference_core()
                .run_open_loop(&trace);
            match &mut merged {
                None => merged = Some(m),
                Some(acc) => acc.merge(&m),
            }
        }
        let mut b = merged.unwrap();
        assert_equiv_metrics(&mut a, &mut b, &format!("open-loop-sharded/seed{seed}"));
    }
}

#[test]
fn sharded_runs_reproducible_with_full_coordination() {
    // Reactive autoscale + the global pre-warm heuristic exercise the
    // whole barrier protocol: shard reports, merged policy ticks,
    // ScaleTo splits and power-of-d SpawnPrewarm placement. The run must
    // be bit-reproducible under (seed, shards) regardless of thread
    // scheduling, and the scaling machinery must actually fire.
    for &shards in &[2usize, 3] {
        let mut c = cfg("hiku", 24, 30.0);
        c.cluster.workers = 6;
        c.sim.shards = shards;
        c.cluster.prewarm = true;
        c.autoscale.policy = "reactive".into();
        c.autoscale.max_workers = 12;
        c.autoscale.cooldown_s = 2.0;
        let mut a = run_once(&c, 7).unwrap();
        let mut b = run_once(&c, 7).unwrap();
        assert_equiv_metrics(&mut a, &mut b, &format!("coordinated/shards{shards}"));
        assert_eq!(a.completed, a.issued, "closed loop must drain");
        assert!(a.completed > 100, "suspiciously few requests");
    }
}

#[test]
fn fair_pull_mode_reproducible_serial_and_sharded() {
    // The fair dispatcher's determinism contract (DESIGN.md §8): with
    // DRR draining, per-function caps, weights and adaptive deadlines
    // all active, pull mode stays bit-reproducible per (seed, shards) —
    // the DRR cursor/deficit state is router-local and a pure function
    // of the push/pop history. Serial first:
    let mut c = cfg("hiku", 20, 25.0);
    c.workload.copies = 1;
    c.dispatch.mode = "pull".into();
    c.dispatch.queue_cap = 16;
    c.dispatch.queue_caps = "0:8".into();
    c.dispatch.weights = "0:2".into();
    for seed in SEEDS {
        let mut a = run_once(&c, seed).unwrap();
        let mut b = run_once(&c, seed).unwrap();
        assert_eq!(
            a.summary_json().to_string_compact(),
            b.summary_json().to_string_compact(),
            "serial fair pull diverged (seed {seed})"
        );
        assert!(a.enqueued > 0, "fair pull must actually park (seed {seed})");
    }
    // Sharded, with cross-shard handoff live: the shared hot-monopoly
    // trace overloads the odd-index donor shard(s) with 24/s of
    // chameleon (+ background dd pairs) while even indices carry a
    // light round-robin filler (so recipient shards stay pending-free
    // and eligible), and the coordinator steals at barriers; the DRR
    // donation order must reproduce bit-for-bit at 2 and 4 shards
    // (4 workers split 2+2 and 1+1+1+1).
    let trace = monopoly_trace(24.0, 20.0, true);
    for &shards in &[2usize, 4] {
        let mut c = cfg("hiku", 1, 20.0);
        c.cluster.workers = 4;
        c.sim.shards = shards;
        c.dispatch.mode = "pull".into();
        c.dispatch.max_wait_s = 1.0;
        c.dispatch.queue_cap = 32;
        c.dispatch.weights = "0:2".into();
        let mut a = run_trace(&c, &trace, 5).expect("sharded fair pull run");
        let mut b = run_trace(&c, &trace, 5).expect("sharded fair pull run");
        assert_eq!(
            a.summary_json().to_string_compact(),
            b.summary_json().to_string_compact(),
            "sharded fair pull diverged (shards {shards})"
        );
        assert_eq!(a.issued, a.completed, "handoffs must not lose requests");
        if shards == 2 {
            assert!(a.stolen > 0, "the imbalance trace must trigger handoffs");
        }
    }
}

#[test]
fn sharded_scheduled_events_apply_at_epochs() {
    let mut c = cfg("hiku", 12, 30.0);
    c.cluster.workers = 4;
    c.sim.shards = 2;
    c.autoscale.policy = "scheduled".into();
    c.autoscale.events = "5,9,-20".into();
    let mut a = run_once(&c, 3).unwrap();
    let mut b = run_once(&c, 3).unwrap();
    assert_equiv_metrics(&mut a, &mut b, "scheduled/shards2");
    assert!(
        a.scale_event_count() >= 2,
        "scheduled events must reach the shards: {:?}",
        a.scaling_timeline
    );
    assert_eq!(a.scaling_timeline.first().map(|&(_, w)| w), Some(4));
}

/// Batch-coalesced completions ([`Cluster::complete_batch`]) must be
/// state- and result-identical to one-at-a-time dispatch, in both
/// admission modes, including queued-start handoffs and keep-alive
/// sweeps interleaved between batches.
#[test]
fn prop_batched_completions_equal_sequential() {
    check("batch-vs-sequential", PropConfig { cases: 120, ..Default::default() }, |rng, size| {
        let workers = 1 + rng.index(3);
        let elastic = rng.index(2) == 0;
        let ccfg = ClusterConfig { workers, mem_mb: 2048, concurrency: 2, ..Default::default() };
        let mut a = Cluster::new(&ccfg); // batched
        let mut b = Cluster::new(&ccfg); // sequential reference
        let mut busy: Vec<Vec<SandboxId>> = vec![Vec::new(); workers];
        let mut t = 0.0;
        for _ in 0..size * 3 {
            t += 0.25;
            match rng.index(4) {
                0 | 1 => {
                    let w = rng.index(workers);
                    let f = rng.index(5);
                    if elastic {
                        let ia = a.assign_elastic(w, 0, f, 256, t);
                        let ib = b.assign_elastic(w, 0, f, 256, t);
                        prop_assert!(ia == ib, "assign diverged: {:?} vs {:?}", ia, ib);
                        busy[w].push(ia.sandbox);
                    } else {
                        let oa = a.assign(w, 0, f, 256, t);
                        let ob = b.assign(w, 0, f, 256, t);
                        prop_assert!(oa == ob, "assign diverged: {:?} vs {:?}", oa, ob);
                        if let AssignOutcome::Started(i) = oa {
                            busy[w].push(i.sandbox);
                        }
                    }
                }
                2 => {
                    // Batch-complete a random prefix of one worker's busy
                    // executions in one call vs one at a time.
                    let w = rng.index(workers);
                    if busy[w].is_empty() {
                        continue;
                    }
                    let k = 1 + rng.index(busy[w].len());
                    let batch: Vec<SandboxId> = busy[w].drain(..k).collect();
                    let got = a.complete_batch(w, &batch, elastic, t);
                    prop_assert!(got.len() == batch.len(), "batch result length");
                    for (i, &sb) in batch.iter().enumerate() {
                        let want = if elastic {
                            let (expiry, evicted) = b.complete_elastic(w, sb, t);
                            BatchCompletion { expiry, started: None, evicted }
                        } else {
                            let (expiry, started) = b.complete(w, sb, t);
                            BatchCompletion { expiry, started, evicted: Vec::new() }
                        };
                        prop_assert!(
                            got[i] == want,
                            "completion {} diverged: {:?} vs {:?}",
                            i,
                            got[i],
                            want
                        );
                        // A queued request started on the freed slot: its
                        // sandbox is busy again (both sides identical).
                        if let Some(info) = &got[i].started {
                            busy[w].push(info.sandbox);
                        }
                    }
                }
                _ => {
                    let w = rng.index(workers);
                    let ea = a.sweep_keepalive(w, t - 3.0);
                    let eb = b.sweep_keepalive(w, t - 3.0);
                    prop_assert!(ea == eb, "sweep diverged: {:?} vs {:?}", ea, eb);
                }
            }
            // Full-state cross-check after every op.
            prop_assert!(a.loads() == b.loads(), "loads diverged");
            prop_assert!(
                a.total_running() == b.total_running() && a.total_queued() == b.total_queued(),
                "aggregate totals diverged"
            );
            for f in 0..5 {
                prop_assert!(
                    a.warm_nonbusy(f) == b.warm_nonbusy(f),
                    "warm supply diverged at f={}",
                    f
                );
            }
            prop_assert!(a.load_summary() == b.load_summary(), "load summaries diverged");
        }
        Ok(())
    });
}

// ---- R2 waiver contract (DESIGN.md §12) ---------------------------------
//
// Every `Instant::now` surviving in the sim path carries a
// `detlint:allow(R2)` waiver justified as "write-only telemetry": the
// phase profiler may read the wall clock but must never influence
// simulation state. This pins that justification as a bit-identity
// property — per shard count, a profiled run must reproduce the plain
// run's summary exactly (minus the gated `phases` key), and the serial
// profiled run must also match the reference engine.

/// `summary_json()` with one top-level key dropped (the profile block is
/// the only legitimate delta between a plain and a profiled run).
fn summary_without(m: &mut RunMetrics, key: &str) -> String {
    match m.summary_json() {
        Json::Obj(mut obj) => {
            obj.remove(key);
            Json::Obj(obj).to_string_compact()
        }
        other => other.to_string_compact(),
    }
}

#[test]
fn r2_waived_profiling_sites_are_write_only() {
    for shards in [1usize, 2, 4] {
        let mut plain_cfg = cfg("hiku", 24, 25.0);
        plain_cfg.cluster.workers = 8;
        plain_cfg.dispatch.mode = "pull".into();
        plain_cfg.sim.shards = shards;
        let mut prof_cfg = plain_cfg.clone();
        prof_cfg.telemetry.phase_profile = true;

        let mut plain = run_once(&plain_cfg, 11).expect("plain run");
        let mut prof = run_once(&prof_cfg, 11).expect("profiled run");
        assert_eq!(
            plain.events_processed, prof.events_processed,
            "shards={shards}: profiling changed the event stream"
        );
        assert_eq!(
            plain.peak_event_queue, prof.peak_event_queue,
            "shards={shards}: profiling changed queue dynamics"
        );
        assert!(
            prof.summary_json().get("phases").is_some(),
            "shards={shards}: profiled summary must carry the phases block"
        );
        assert_eq!(
            plain.summary_json().to_string_compact(),
            summary_without(&mut prof, "phases"),
            "shards={shards}: profiled summary must equal the plain one minus `phases`"
        );

        if shards == 1 {
            // Serial path: the profiled run must also match the seed
            // reference engine (which has no profiler at all).
            let mut r = run_once_reference(&plain_cfg, 11).expect("reference run");
            assert_eq!(
                r.summary_json().to_string_compact(),
                summary_without(&mut prof, "phases"),
                "profiled serial run diverged from the reference engine"
            );
        }
    }
}
