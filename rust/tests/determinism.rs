//! Determinism equivalence suite for the event-core overhaul.
//!
//! The calendar-queue event core plus the incremental load/warm-supply
//! accounting must be *bit-identical* to the seed implementation (binary
//! heap + full-cluster scans), which lives on behind the `ref-heap`
//! feature as `Simulation::with_reference_core`. For every scheduler ×
//! {elastic, queue} × autoscale policy combination we run the same
//! (config, seed) on both engines and require identical `summary_json()`
//! output, event counts, and peak queue depth across ≥3 seeds.

#![cfg(feature = "ref-heap")]

use hiku::config::Config;
use hiku::metrics::RunMetrics;
use hiku::scheduler::{ALL_SCHEDULERS, PAPER_SCHEDULERS};
use hiku::sim::{run_once, run_once_reference, run_trace, run_trace_reference};
use hiku::workload::azure::SyntheticTrace;
use hiku::workload::loadgen::OpenLoopTrace;

const SEEDS: [u64; 3] = [1, 2, 3];

fn cfg(sched: &str, vus: usize, dur: f64) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.workload.vus = vus;
    c.workload.duration_s = dur;
    c
}

fn assert_equiv_metrics(a: &mut RunMetrics, b: &mut RunMetrics, label: &str) {
    assert_eq!(
        a.events_processed, b.events_processed,
        "{label}: event counts diverged (calendar {} vs ref {})",
        a.events_processed, b.events_processed
    );
    assert_eq!(
        a.peak_event_queue, b.peak_event_queue,
        "{label}: peak queue depth diverged"
    );
    assert_eq!(
        a.summary_json().to_string_compact(),
        b.summary_json().to_string_compact(),
        "{label}: summaries diverged"
    );
}

fn assert_equiv(c: &Config, seed: u64, label: &str) {
    let mut a = run_once(c, seed).unwrap_or_else(|e| panic!("{label}: {e}"));
    let mut b = run_once_reference(c, seed).unwrap_or_else(|e| panic!("{label}: {e}"));
    assert_equiv_metrics(&mut a, &mut b, &format!("{label}/seed{seed}"));
}

#[test]
fn all_schedulers_elastic_static() {
    for sched in ALL_SCHEDULERS {
        for seed in SEEDS {
            assert_equiv(&cfg(sched, 10, 20.0), seed, sched);
        }
    }
}

#[test]
fn paper_schedulers_queue_mode() {
    // Hard admission queues (elastic=false) exercise the queued-start path
    // and the total_queued aggregate.
    for sched in PAPER_SCHEDULERS {
        for seed in SEEDS {
            let mut c = cfg(sched, 10, 20.0);
            c.cluster.elastic = false;
            assert_equiv(&c, seed, &format!("{sched}/queue"));
        }
    }
}

#[test]
fn autoscale_policies_equivalent() {
    // Scale events churn the active set, which the incremental aggregates
    // must track exactly (contributions move in/out at the boundary).
    for sched in ["hiku", "least-connections", "ch-bl"] {
        for policy in ["scheduled", "reactive", "predictive"] {
            for seed in SEEDS {
                let mut c = cfg(sched, 12, 25.0);
                c.autoscale.policy = policy.into();
                c.autoscale.max_workers = 9;
                c.autoscale.cooldown_s = 3.0;
                if policy == "scheduled" {
                    c.autoscale.events = "4,8,-15,-18".into();
                }
                assert_equiv(&c, seed, &format!("{sched}/{policy}"));
            }
        }
    }
}

#[test]
fn prewarm_heuristic_equivalent() {
    // cluster.prewarm drives on_prewarm_tick (warm-supply reads) and
    // spawn_prewarm (min-load-fitting placement) every simulated second.
    for sched in ["hiku", "random"] {
        for seed in SEEDS {
            let mut c = cfg(sched, 10, 20.0);
            c.cluster.prewarm = true;
            assert_equiv(&c, seed, &format!("{sched}/prewarm"));
        }
    }
}

#[test]
fn multi_instance_equivalent() {
    // Several scheduler instances = several independent load views, each
    // with its own min-load index.
    for seed in SEEDS {
        let mut c = cfg("hiku", 12, 20.0);
        c.scheduler.instances = 3;
        assert_equiv(&c, seed, "hiku/instances=3");
    }
}

#[test]
fn hiku_fallback_variants_equivalent() {
    // Custom fallbacks route through the same ctx helpers.
    for sched in ["hiku+random", "hiku+ch-bl"] {
        for seed in SEEDS {
            assert_equiv(&cfg(sched, 10, 15.0), seed, sched);
        }
    }
}

#[test]
fn open_loop_trace_equivalent() {
    let c = cfg("hiku", 1, 60.0);
    let gen = SyntheticTrace::generate(40, 60.0, 777);
    let trace = OpenLoopTrace::from_synthetic(&gen.invocations, 40);
    for seed in SEEDS {
        let mut a = run_trace(&c, &trace, seed).expect("trace run");
        let mut b = run_trace_reference(&c, &trace, seed).expect("trace ref run");
        assert_equiv_metrics(&mut a, &mut b, &format!("open-loop/seed{seed}"));
    }
}

#[test]
fn repeated_runs_identical_on_new_core() {
    // The new core is also self-deterministic (not just ref-equivalent).
    let c = cfg("hiku", 10, 20.0);
    let mut a = run_once(&c, 7).unwrap();
    let mut b = run_once(&c, 7).unwrap();
    assert_eq!(a.summary_json().to_string_compact(), b.summary_json().to_string_compact());
}
