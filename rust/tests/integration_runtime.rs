//! Integration tests: the PJRT runtime against the real AOT artifacts.
//!
//! These REQUIRE `make artifacts` to have run (the Makefile test target
//! guarantees the ordering). They verify the whole python -> HLO text ->
//! rust -> PJRT -> numerics chain.

use hiku::runtime::{Engine, Manifest};

fn engine(cap: usize) -> Engine {
    let m = Manifest::load("artifacts")
        .expect("artifacts/manifest.json missing — run `make artifacts`");
    Engine::new(m, cap).expect("PJRT engine")
}

#[test]
fn manifest_covers_all_functionbench_apps() {
    let m = Manifest::load("artifacts").expect("run `make artifacts`");
    let mut names = m.names();
    names.sort_unstable();
    assert_eq!(
        names,
        vec![
            "chameleon",
            "dd",
            "float_operation",
            "gzip_compression",
            "json_dumps_loads",
            "linpack",
            "matmul",
            "pyaes"
        ]
    );
}

#[test]
fn goldens_verify_end_to_end() {
    // The CORE cross-language correctness signal: rust-side PJRT execution
    // reproduces the digests jax computed at AOT time, for every payload
    // and both golden seeds.
    let mut e = engine(8);
    let n = e.verify_goldens().expect("golden verification");
    assert_eq!(n, 16, "8 payloads x 2 seeds");
}

#[test]
fn cold_warm_asymmetry_is_real() {
    // Table I's premise: initialization (XLA compile) dominates a cold
    // start. Warm executions must be much faster than cold ones.
    let mut e = engine(8);
    let mut cold_total = 0.0;
    let mut warm_total = 0.0;
    for name in ["matmul", "pyaes", "json_dumps_loads"] {
        let cold = e.execute(name, 3).unwrap();
        assert!(cold.cold);
        let warm = e.execute(name, 4).unwrap();
        assert!(!warm.cold);
        cold_total += cold.total_s;
        warm_total += warm.total_s;
    }
    assert!(
        cold_total > 1.5 * warm_total,
        "cold {cold_total:.4}s not >> warm {warm_total:.4}s"
    );
}

#[test]
fn digests_differ_across_seeds_and_payloads() {
    let mut e = engine(8);
    let a = e.execute("pyaes", 1).unwrap().digest;
    let b = e.execute("pyaes", 2).unwrap().digest;
    let c = e.execute("dd", 1).unwrap().digest;
    assert_ne!(a, b, "seed must matter");
    assert_ne!(a, c, "payload must matter");
}

#[test]
fn cache_eviction_cycle() {
    let mut e = engine(2);
    e.execute("matmul", 1).unwrap();
    e.execute("pyaes", 1).unwrap();
    let r = e.execute("linpack", 1).unwrap();
    assert_eq!(r.evicted, vec!["matmul".to_string()]);
    // Re-touching the evicted payload is cold again.
    let r2 = e.execute("matmul", 1).unwrap();
    assert!(r2.cold, "evicted payload must cold-start");
    assert_eq!(e.total_cold, 4);
    assert_eq!(e.total_warm, 0);
}

#[test]
fn warm_executions_are_deterministic() {
    let mut e = engine(4);
    let r1 = e.execute("gzip_compression", 42).unwrap();
    let r2 = e.execute("gzip_compression", 42).unwrap();
    let r3 = e.execute("gzip_compression", 42).unwrap();
    assert_eq!(r1.digest, r2.digest);
    assert_eq!(r2.digest, r3.digest);
}
