//! End-to-end integration: the real-time threaded cluster serving the AOT
//! PJRT payloads through the Hiku scheduler. Wall-clock test — kept small.

use hiku::config::Config;
use hiku::server::serve_n_requests;

fn cfg(sched: &str) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.cluster.workers = 2;
    c.workload.vus = 4;
    // Fast think times: this is wall-clock.
    c.workload.think_min_s = 0.001;
    c.workload.think_max_s = 0.005;
    c
}

#[test]
fn serves_requests_end_to_end() {
    let mut m = serve_n_requests(&cfg("hiku"), 40).expect("serving failed");
    assert_eq!(m.completed, 40);
    assert!(m.cold_starts >= 1, "first touches must cold-start");
    assert!(m.warm_starts >= 1, "repeats must warm-start");
    assert!(m.mean_latency_ms() > 0.0);
    let j = m.summary_json();
    assert_eq!(j.get("scheduler").unwrap().as_str(), Some("hiku"));
}

#[test]
fn random_scheduler_also_serves() {
    let m = serve_n_requests(&cfg("random"), 20).expect("serving failed");
    assert_eq!(m.completed, 20);
    assert_eq!(m.cold_starts + m.warm_starts, 20);
}
