//! End-to-end smoke for the HTTP front door (DESIGN.md §13): the
//! in-tree ingress on an ephemeral port, exercised both with raw
//! sockets (route/parser behavior) and with the open-loop loadgen
//! (conservation + summary-shape parity with the Server API).
//!
//! Wall-clock tests on the stub runtime backend — no AOT artifacts
//! needed, kept small.

use hiku::config::Config;
use hiku::server::http::HttpIngress;
use hiku::server::{InvokeOutcome, Server};
use hiku::util::json::Json;
use hiku::workload::loadgen::{loadgen_schedule, run_http_loadgen, LoadgenOpts};
use std::io::{Read, Write};
use std::net::TcpStream;

fn cfg() -> Config {
    let mut c = Config::default();
    c.runtime.backend = "stub".into();
    c.scheduler.name = "hiku".into();
    c.dispatch.mode = "pull".into();
    c.cluster.workers = 2;
    c.http.io_threads = 4;
    c
}

/// One raw HTTP exchange: send `req` verbatim, read the reply to EOF
/// (callers set `Connection: close` so the server ends the stream).
fn raw(addr: &str, req: &str) -> String {
    let mut s = TcpStream::connect(addr).expect("connect");
    s.write_all(req.as_bytes()).expect("write");
    let mut out = String::new();
    s.read_to_string(&mut out).expect("read");
    out
}

fn get(addr: &str, path: &str) -> String {
    raw(addr, &format!("GET {path} HTTP/1.1\r\nHost: x\r\nConnection: close\r\n\r\n"))
}

fn body_of(resp: &str) -> &str {
    resp.split("\r\n\r\n").nth(1).unwrap_or("")
}

#[test]
fn routes_and_parser_respond_correctly() {
    let ingress = HttpIngress::start(&cfg(), "127.0.0.1:0").expect("start");
    let addr = ingress.local_addr().to_string();

    let health = get(&addr, "/healthz");
    assert!(health.starts_with("HTTP/1.1 200"), "healthz: {health}");
    assert_eq!(body_of(&health), "{\"ok\":true}");

    let summary = get(&addr, "/summary");
    assert!(summary.starts_with("HTTP/1.1 200"), "summary: {summary}");
    Json::parse(body_of(&summary)).expect("summary must be valid JSON");

    // One real invocation over the wire.
    let inv = raw(
        &addr,
        "POST /invoke/0 HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
    );
    assert!(inv.starts_with("HTTP/1.1 200"), "invoke: {inv}");
    let j = Json::parse(body_of(&inv)).expect("invoke reply must be valid JSON");
    assert_eq!(j.get("outcome").and_then(Json::as_str), Some("completed"));
    assert_eq!(j.get("function").and_then(Json::as_u64), Some(0));

    // Speculative warmup is accepted asynchronously.
    let pre = raw(
        &addr,
        "POST /prewarm/1 HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
    );
    assert!(pre.starts_with("HTTP/1.1 202"), "prewarm: {pre}");

    // Unknown routes and out-of-range function ids are 404.
    assert!(get(&addr, "/nope").starts_with("HTTP/1.1 404"));
    let far = raw(
        &addr,
        "POST /invoke/99999 HTTP/1.1\r\nHost: x\r\nContent-Length: 0\r\nConnection: close\r\n\r\n",
    );
    assert!(far.starts_with("HTTP/1.1 404"), "oob function: {far}");

    // A garbage request line is a 400, not a hang or a crash.
    let bad = raw(&addr, "GARBAGE\r\n\r\n");
    assert!(bad.starts_with("HTTP/1.1 400"), "malformed: {bad}");

    // Keep-alive: two requests down one connection both answer.
    let mut s = TcpStream::connect(&addr).expect("connect");
    for _ in 0..2 {
        s.write_all(b"GET /healthz HTTP/1.1\r\nHost: x\r\n\r\n").expect("write");
        let mut resp = String::new();
        let mut buf = [0u8; 512];
        while !resp.contains("{\"ok\":true}") {
            let n = s.read(&mut buf).expect("read");
            assert!(n > 0, "connection closed mid-response: {resp}");
            resp.push_str(&String::from_utf8_lossy(&buf[..n]));
        }
        assert!(resp.starts_with("HTTP/1.1 200"), "keep-alive: {resp}");
    }

    let mut m = ingress.stop().expect("stop");
    assert_eq!(m.completed, 1);
    assert_eq!(m.arrivals, m.completed + m.rejected + m.failed);
    assert!(m.mean_latency_ms() > 0.0);
}

#[test]
fn loadgen_run_conserves_requests_and_matches_server_api_summary_shape() {
    let c = cfg();
    let ingress = HttpIngress::start(&c, "127.0.0.1:0").expect("start");
    let opts = LoadgenOpts {
        addr: ingress.local_addr().to_string(),
        requests: 200,
        rate_rps: 500.0,
        connections: 4,
        num_functions: c.num_functions(),
        seed: 7,
        ..Default::default()
    };
    let report = run_http_loadgen(&opts).expect("loadgen");

    // Client-side conservation: every scheduled request accounted for,
    // and on an unbounded localhost queue all of them complete.
    assert!(report.accounted(), "loadgen accounting must balance");
    assert_eq!(report.sent, 200);
    assert_eq!(report.transport_errors, 0);
    assert_eq!(report.completed, 200);
    assert_eq!(report.rejected + report.failed, 0);

    // Server-side conservation, scraped over the wire after a drain.
    ingress.client().drain().expect("drain");
    let scraped = get(&ingress.local_addr().to_string(), "/summary");
    let http_summary = Json::parse(body_of(&scraped)).expect("summary JSON");
    let arrivals = http_summary.get("arrivals").and_then(Json::as_u64).unwrap();
    let completed = http_summary.get("completed").and_then(Json::as_u64).unwrap();
    let rejected = http_summary.get("rejected").and_then(Json::as_u64).unwrap();
    let failed = http_summary.get("failed").and_then(Json::as_u64).unwrap();
    let outstanding = http_summary.get("outstanding").and_then(Json::as_u64).unwrap();
    assert_eq!(outstanding, 0, "drained server must have nothing in flight");
    assert_eq!(arrivals, completed + rejected + failed);
    assert_eq!(completed, 200);

    // Shape parity: replay the same schedule through the Server API and
    // require the identical summary key set (HTTP adds nothing and
    // loses nothing relative to in-process callers).
    let server = Server::start(&c).expect("server");
    for &(_, f) in &loadgen_schedule(&opts) {
        let out = server.invoke(f).expect("invoke");
        assert_ne!(out, InvokeOutcome::Rejected, "unbounded queue must admit");
    }
    server.drain().expect("drain");
    let api_summary = server.summary().expect("summary");
    let api_keys: Vec<&String> = api_summary.as_obj().unwrap().keys().collect();
    let http_keys: Vec<&String> = http_summary.as_obj().unwrap().keys().collect();
    assert_eq!(http_keys, api_keys, "HTTP /summary shape must match the Server API");
    let mut m = server.shutdown().expect("shutdown");
    assert_eq!(m.completed, 200);
    assert_eq!(m.arrivals, m.completed + m.rejected + m.failed);
    assert!(m.mean_latency_ms() > 0.0);

    let server_metrics = ingress.stop().expect("stop");
    assert_eq!(server_metrics.completed, 200);
    assert_eq!(
        server_metrics.arrivals,
        server_metrics.completed + server_metrics.rejected + server_metrics.failed
    );
}
