//! Integration tests: the full simulated cluster across all schedulers.

use hiku::config::Config;
use hiku::scheduler::{ALL_SCHEDULERS, PAPER_SCHEDULERS};
use hiku::sim::run_once;

fn cfg(sched: &str, vus: usize, dur: f64) -> Config {
    let mut c = Config::default();
    c.scheduler.name = sched.into();
    c.workload.vus = vus;
    c.workload.duration_s = dur;
    c
}

#[test]
fn every_scheduler_completes_a_run() {
    for sched in ALL_SCHEDULERS {
        let m = run_once(&cfg(sched, 20, 20.0), 11).expect(sched);
        assert_eq!(m.issued, m.completed, "{sched}");
        assert!(m.completed > 200, "{sched}: only {} requests", m.completed);
    }
}

#[test]
fn fairness_identical_scripts_across_schedulers() {
    // The paper's seeding guarantee: with the same seed, every scheduler
    // sees the same invocation order and think times. We verify through
    // the workload layer (scripts are scheduler-independent by
    // construction) and through total issued counts being driven only by
    // response times.
    use hiku::workload::Workload;
    let base = cfg("hiku", 10, 30.0);
    let w1 = Workload::generate(&base.workload, 40, 99);
    let w2 = Workload::generate(&base.workload, 40, 99);
    for (a, b) in w1.vus.iter().zip(&w2.vus) {
        assert_eq!(a.steps, b.steps);
    }
    assert_eq!(w1.weights, w2.weights);
}

#[test]
fn paper_orderings_hold_at_high_concurrency() {
    // The paper's headline orderings (Figs 11, 13, 16) at 100 VUs,
    // averaged over 3 seeds to damp noise.
    let mut lat = std::collections::BTreeMap::new();
    let mut cold = std::collections::BTreeMap::new();
    let mut thru = std::collections::BTreeMap::new();
    for sched in PAPER_SCHEDULERS {
        let (mut l, mut c, mut t) = (0.0, 0.0, 0.0);
        for seed in [1, 2, 3] {
            let mut m = run_once(&cfg(sched, 100, 60.0), seed).unwrap();
            l += m.mean_latency_ms();
            c += m.cold_rate();
            t += m.completed as f64;
        }
        lat.insert(sched, l / 3.0);
        cold.insert(sched, c / 3.0);
        thru.insert(sched, t / 3.0);
    }
    for other in ["ch-bl", "random", "least-connections"] {
        assert!(
            lat["hiku"] < lat[other],
            "latency: hiku {} !< {other} {}",
            lat["hiku"],
            lat[other]
        );
        assert!(
            cold["hiku"] < cold[other],
            "cold rate: hiku {} !< {other} {}",
            cold["hiku"],
            cold[other]
        );
        assert!(
            thru["hiku"] > thru[other],
            "throughput: hiku {} !> {other} {}",
            thru["hiku"],
            thru[other]
        );
    }
}

#[test]
fn load_balancing_hiku_comparable_to_least_connections() {
    // Fig 15: Hiku's CV is comparable to least-connections and clearly
    // better than CH-BL.
    let mut cv = std::collections::BTreeMap::new();
    for sched in PAPER_SCHEDULERS {
        let mut acc = 0.0;
        for seed in [4, 5, 6] {
            acc += run_once(&cfg(sched, 100, 60.0), seed).unwrap().mean_cv();
        }
        cv.insert(sched, acc / 3.0);
    }
    assert!(
        (cv["hiku"] - cv["least-connections"]).abs() < 0.08,
        "hiku {} vs lc {} not comparable",
        cv["hiku"],
        cv["least-connections"]
    );
    assert!(cv["hiku"] < cv["ch-bl"], "hiku {} !< ch-bl {}", cv["hiku"], cv["ch-bl"]);
}

#[test]
fn concurrency_gap_widens_with_vus() {
    // Fig 17: hiku's relative advantage over CH-BL grows from 20 -> 100 VUs.
    let ratio = |vus: usize| {
        let h: f64 = [7, 8]
            .iter()
            .map(|&s| run_once(&cfg("hiku", vus, 60.0), s).unwrap().rps())
            .sum::<f64>()
            / 2.0;
        let c: f64 = [7, 8]
            .iter()
            .map(|&s| run_once(&cfg("ch-bl", vus, 60.0), s).unwrap().rps())
            .sum::<f64>()
            / 2.0;
        h / c
    };
    let r20 = ratio(20);
    let r100 = ratio(100);
    assert!(
        r100 > r20,
        "advantage must grow with concurrency: 20 VUs {r20:.3}, 100 VUs {r100:.3}"
    );
    assert!((0.9..1.15).contains(&r20), "at 20 VUs performance should be similar: {r20:.3}");
}

#[test]
fn queue_mode_ablation_still_conserves() {
    // The hard-FIFO worker mode (elastic = false) remains a valid system.
    let mut c = cfg("hiku", 30, 20.0);
    c.cluster.elastic = false;
    let m = run_once(&c, 12).unwrap();
    assert_eq!(m.issued, m.completed);
    assert!(m.queue_delay_ms.mean() >= 0.0);
}

#[test]
fn keep_alive_expiry_creates_cold_starts_at_low_load() {
    // With one VU and a long think time, instances expire between
    // invocations when keep-alive is short -> every request cold.
    let mut c = cfg("hiku", 1, 30.0);
    c.cluster.keep_alive_s = 0.05;
    c.workload.think_min_s = 0.5;
    c.workload.think_max_s = 1.0;
    let m_short = run_once(&c, 13).unwrap();
    c.cluster.keep_alive_s = 3600.0;
    let m_long = run_once(&c, 13).unwrap();
    assert!(
        m_short.cold_rate() > m_long.cold_rate() + 0.3,
        "keep-alive must matter at low load: short {} vs long {}",
        m_short.cold_rate(),
        m_long.cold_rate()
    );
}

#[test]
fn single_worker_degenerate_cluster() {
    let mut c = cfg("hiku", 5, 10.0);
    c.cluster.workers = 1;
    let m = run_once(&c, 14).unwrap();
    assert_eq!(m.issued, m.completed);
    assert!(m.mean_cv() == 0.0, "one worker cannot be imbalanced");
}

#[test]
fn more_workers_reduce_latency_under_load() {
    let mut c5 = cfg("hiku", 100, 40.0);
    c5.cluster.workers = 5;
    let mut c10 = cfg("hiku", 100, 40.0);
    c10.cluster.workers = 10;
    let mut m5 = run_once(&c5, 15).unwrap();
    let mut m10 = run_once(&c10, 15).unwrap();
    assert!(
        m10.mean_latency_ms() < m5.mean_latency_ms(),
        "10 workers {} !< 5 workers {}",
        m10.mean_latency_ms(),
        m5.mean_latency_ms()
    );
}

#[test]
fn extension_schedulers_behave_reasonably() {
    // power-of-d and rj-ch should land between random and least-connections
    // on load balance at high concurrency.
    let cv = |sched: &str| run_once(&cfg(sched, 100, 40.0), 16).unwrap().mean_cv();
    let random = cv("random");
    let lc = cv("least-connections");
    let pod = cv("power-of-d");
    assert!(pod < random, "power-of-2 must balance better than random");
    assert!(lc < random, "lc must balance better than random");
}

// ---- extension features ----------------------------------------------

#[test]
fn hiku_custom_fallback_runs() {
    for name in ["hiku+random", "hiku+ch-bl", "hiku+power-of-d"] {
        let m = run_once(&cfg(name, 20, 20.0), 21).expect(name);
        assert_eq!(m.issued, m.completed, "{name}");
    }
    // Recursive fallback is rejected.
    assert!(run_once(&cfg("hiku+hiku", 5, 5.0), 21).is_err());
    assert!(run_once(&cfg("hiku+bogus", 5, 5.0), 21).is_err());
}

#[test]
fn autoscale_adds_capacity() {
    let mut c = cfg("hiku", 100, 120.0);
    c.cluster.workers = 3;
    c.autoscale.policy = "scheduled".into();
    let mut static3 = run_once(&c, 22).unwrap();
    c.autoscale.events = "30;60".into();
    let mut scaled = run_once(&c, 22).unwrap();
    assert!(
        scaled.completed > static3.completed,
        "scaling up must add throughput: {} vs {}",
        scaled.completed,
        static3.completed
    );
    assert!(scaled.mean_latency_ms() < static3.mean_latency_ms());
    // Totals per worker: 5 columns, the late joiners saw traffic.
    let totals = scaled.imbalance.totals();
    assert_eq!(totals.len(), 5);
    assert!(totals[3] > 0.0 && totals[4] > 0.0, "new workers idle: {totals:?}");
}

#[test]
fn autoscale_all_schedulers_route_to_new_worker() {
    for sched in ALL_SCHEDULERS {
        let mut c = cfg(sched, 40, 60.0);
        c.cluster.workers = 3;
        c.autoscale.policy = "scheduled".into();
        c.autoscale.events = "20".into();
        let m = run_once(&c, 23).expect(sched);
        let totals = m.imbalance.totals();
        assert_eq!(totals.len(), 4, "{sched}");
        assert!(totals[3] > 0.0, "{sched}: new worker never used: {totals:?}");
    }
}

#[test]
fn multi_scheduler_instances_conserve() {
    let mut c = cfg("hiku", 40, 30.0);
    c.scheduler.instances = 4;
    let m = run_once(&c, 24).unwrap();
    assert_eq!(m.issued, m.completed);
    assert!(m.completed > 400);
}

#[test]
fn multi_scheduler_degrades_gracefully() {
    // Sharding the schedulers costs hiku some pull hits but must not
    // change the system's correctness or collapse throughput.
    let mut c1 = cfg("hiku", 100, 60.0);
    let mut c4 = cfg("hiku", 100, 60.0);
    c1.scheduler.instances = 1;
    c4.scheduler.instances = 4;
    let m1 = run_once(&c1, 25).unwrap();
    let m4 = run_once(&c4, 25).unwrap();
    assert!(m4.completed as f64 > 0.7 * m1.completed as f64);
    // Partitioned idle queues lose pull opportunities; averaged over seeds
    // the cold rate rises (see ablation_multisched) — per-seed it may
    // wobble, so only bound the degradation here.
    assert!(m4.cold_rate() < m1.cold_rate() + 0.35);
}

#[test]
fn open_loop_trace_replay() {
    use hiku::sim::run_trace;
    use hiku::workload::azure::SyntheticTrace;
    use hiku::workload::loadgen::OpenLoopTrace;
    let gen = SyntheticTrace::generate(40, 60.0, 26);
    let trace = OpenLoopTrace::from_synthetic(&gen.invocations, 40);
    let c = cfg("hiku", 1, 60.0);
    let m = run_trace(&c, &trace, 26).unwrap();
    assert_eq!(m.issued, m.completed);
    let in_window = gen.invocations.iter().filter(|&&(t, _)| t < 60.0).count() as u64;
    assert_eq!(m.issued, in_window, "every trace arrival inside the window is served");
}

#[test]
fn scale_down_drains_lifo() {
    for sched in ["hiku", "ch-bl", "least-connections", "consistent"] {
        let mut c = cfg(sched, 40, 90.0);
        c.cluster.workers = 5;
        // Drain two workers at t=30, re-add one at t=60.
        c.autoscale.policy = "scheduled".into();
        c.autoscale.events = "-30;-30;60".into();
        let m = run_once(&c, 27).expect(sched);
        assert_eq!(m.issued, m.completed, "{sched}");
        let totals = m.imbalance.totals();
        // Worker 4 drained at t=30 and never came back; worker 3 returned.
        assert!(totals[4] > 0.0, "{sched}: worker 4 should have early traffic");
        assert!(totals[3] > 0.0, "{sched}: re-added worker 3 must see traffic");
        // Load-aware schedulers must clearly prefer the re-added worker
        // (active 60 s) over the permanently drained one (active 30 s);
        // ring-based ownership depends on which keys each worker holds, so
        // only the weak property holds there.
        if sched == "hiku" || sched == "least-connections" {
            assert!(
                totals[3] > totals[4],
                "{sched}: re-added worker 3 must out-serve drained 4 ({totals:?})"
            );
        }
    }
}

#[test]
fn scale_down_never_removes_last_worker() {
    let mut c = cfg("hiku", 5, 20.0);
    c.cluster.workers = 1;
    c.autoscale.policy = "scheduled".into();
    c.autoscale.events = "-5;-6".into();
    let m = run_once(&c, 28).unwrap();
    assert_eq!(m.issued, m.completed);
    assert!(m.completed > 0);
}
