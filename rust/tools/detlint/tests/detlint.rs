//! Fixture-based self-tests for detlint.
//!
//! Acceptance contract (ISSUE 9): each of R1–R5 demonstrably trips on a
//! known-bad fixture, waived fixtures count as waived, clean fixtures
//! produce nothing, the JSON report shape is pinned, and the real `src/`
//! tree scans clean (every finding waived, every waiver used).

use detlint::report::Report;
use detlint::scan_paths;
use hiku::util::json::Json;
use std::path::PathBuf;

fn fixture(rel: &str) -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures").join(rel)
}

fn scan(rel: &str) -> Report {
    scan_paths(&[fixture(rel)]).expect("fixture scan must succeed")
}

#[test]
fn r1_trips_on_every_iteration_form() {
    let r = scan("sim/r1_bad.rs");
    assert_eq!(r.rule_counts("R1"), (6, 0, 6), "iter/keys/values/for-in/retain/drain");
    assert_eq!(r.findings.len(), 6);
    assert!(!r.clean());
}

#[test]
fn r1_waiver_is_counted_and_consumed() {
    let r = scan("sim/r1_waived.rs");
    assert_eq!(r.rule_counts("R1"), (1, 1, 0));
    assert!(r.clean());
    assert_eq!(r.waivers.len(), 1);
    assert!(r.waivers[0].used);
    assert!(r.unused_waivers().is_empty());
}

#[test]
fn r1_clean_fixture_is_silent() {
    let r = scan("sim/r1_clean.rs");
    assert!(r.findings.is_empty(), "BTreeMap iteration and HashMap lookups are fine");
}

#[test]
fn r2_trips_on_wall_clock_reads() {
    let r = scan("sim/r2_bad.rs");
    assert_eq!(r.rule_counts("R2"), (2, 0, 2), "Instant::now and SystemTime::now");
}

#[test]
fn r2_waivers_cover_standalone_and_trailing_forms() {
    let r = scan("sim/r2_waived.rs");
    assert_eq!(r.rule_counts("R2"), (2, 2, 0));
    assert!(r.clean());
    assert_eq!(r.waivers.len(), 2);
    assert!(r.waivers.iter().all(|w| w.used));
}

#[test]
fn r2_is_allowlisted_in_server_scope() {
    let r = scan("server/r2_clean.rs");
    assert!(r.findings.is_empty(), "server/ owns real wall-clock time");
}

#[test]
fn r3_trips_on_ambient_randomness() {
    let r = scan("util/r3_bad.rs");
    assert_eq!(r.rule_counts("R3"), (3, 0, 3), "thread_rng, from_entropy, RandomState");
}

#[test]
fn r3_waiver_and_seeded_stream() {
    let r = scan("util/r3_waived.rs");
    assert_eq!(r.rule_counts("R3"), (1, 1, 0));
    assert!(r.clean());
    let r = scan("util/r3_clean.rs");
    assert!(r.findings.is_empty(), "Pcg64::new(seed) is the sanctioned source");
}

#[test]
fn r4_trips_alongside_r1_in_merge_paths() {
    let r = scan("stats/r4_bad.rs");
    assert_eq!(r.rule_counts("R1"), (1, 0, 1));
    assert_eq!(r.rule_counts("R4"), (1, 0, 1), "float accumulation over unordered iter");
    assert_eq!(r.findings.len(), 2);
}

#[test]
fn r4_multi_rule_waiver_covers_both_findings() {
    let r = scan("stats/r4_waived.rs");
    assert_eq!(r.rule_counts("R1"), (1, 1, 0));
    assert_eq!(r.rule_counts("R4"), (1, 1, 0));
    assert!(r.clean());
    assert_eq!(r.waivers.len(), 1, "one allow(R1,R4) comment covers both");
    let r = scan("stats/r4_clean.rs");
    assert!(r.findings.is_empty(), "the same loop over BTreeMap is fine");
}

#[test]
fn r5_trips_on_malformed_waivers_which_waive_nothing() {
    let r = scan("sim/r5_bad.rs");
    assert_eq!(r.rule_counts("R5"), (2, 0, 2), "missing justification; unknown rule");
    assert_eq!(r.rule_counts("R2"), (2, 0, 2), "malformed waivers must not excuse");
    assert!(r.waivers.is_empty(), "malformed waivers are findings, not waivers");
}

#[test]
fn r5_good_and_clean_fixtures() {
    let r = scan("sim/r5_good.rs");
    assert_eq!(r.rule_counts("R2"), (1, 1, 0));
    assert!(r.clean());
    let r = scan("sim/r5_clean.rs");
    assert!(r.findings.is_empty());
    assert!(r.waivers.is_empty());
}

#[test]
fn masked_tokens_in_literals_and_comments_do_not_trip() {
    let r = scan("sim/masked_clean.rs");
    assert!(
        r.findings.is_empty(),
        "strings, raw strings, char literals, and comments must be invisible"
    );
}

#[test]
fn fixture_tree_aggregate_counts_are_exact() {
    let r = scan_paths(&[fixture("")]).expect("fixture tree scan");
    assert_eq!(r.files, 16);
    assert!(r.lines > 100);
    assert_eq!(r.rule_counts("R1"), (9, 2, 7));
    assert_eq!(r.rule_counts("R2"), (7, 3, 4));
    assert_eq!(r.rule_counts("R3"), (4, 1, 3));
    assert_eq!(r.rule_counts("R4"), (2, 1, 1));
    assert_eq!(r.rule_counts("R5"), (2, 0, 2));
    assert_eq!(r.findings.len(), 24);
    assert_eq!(r.waivers.len(), 6);
    assert!(r.waivers.iter().all(|w| w.used), "every valid fixture waiver is consumed");
    assert!(r.unused_waivers().is_empty());
    // Findings are sorted by (file, line, rule) so the report is stable.
    let keys: Vec<_> = r.findings.iter().map(|f| (f.file.clone(), f.line, f.rule)).collect();
    let mut sorted = keys.clone();
    sorted.sort();
    assert_eq!(keys, sorted);
}

#[test]
fn json_report_shape_is_pinned() {
    let r = scan_paths(&[fixture("")]).expect("fixture tree scan");
    let text = r.to_json().to_string_pretty();
    let j = Json::parse(&text).expect("report JSON must parse with the in-tree parser");
    assert_eq!(j.get("version").unwrap().as_u64(), Some(1));
    assert_eq!(j.get("tool").unwrap().as_str(), Some("detlint"));
    assert_eq!(j.get("clean").unwrap().as_bool(), Some(false));
    assert_eq!(j.get("files_scanned").unwrap().as_u64(), Some(16));
    assert_eq!(j.at(&["rules", "R1", "total"]).unwrap().as_u64(), Some(9));
    assert_eq!(j.at(&["rules", "R1", "waived"]).unwrap().as_u64(), Some(2));
    assert_eq!(j.at(&["rules", "R1", "unwaived"]).unwrap().as_u64(), Some(7));
    assert_eq!(j.at(&["rules", "R5", "unwaived"]).unwrap().as_u64(), Some(2));
    assert_eq!(j.at(&["waivers", "valid"]).unwrap().as_u64(), Some(6));
    assert_eq!(j.at(&["waivers", "used"]).unwrap().as_u64(), Some(6));
    assert_eq!(j.at(&["waivers", "unused"]).unwrap().as_arr().unwrap().len(), 0);
    let findings = j.get("findings").unwrap().as_arr().unwrap();
    assert_eq!(findings.len(), 24);
    for f in findings {
        assert!(f.get("rule").is_some());
        assert!(f.get("file").is_some());
        assert!(f.get("line").is_some());
        assert!(f.get("message").is_some());
        let waived = f.get("waived").unwrap().as_bool().unwrap();
        assert_eq!(
            f.get("justification").is_some(),
            waived,
            "justification key present iff waived"
        );
    }
}

#[test]
fn repo_src_tree_scans_clean_with_every_waiver_used() {
    let src = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../../src");
    let r = scan_paths(&[src]).expect("src tree scan");
    assert!(r.files > 20, "the whole library tree is in scope");
    let unwaived = r.unwaived();
    assert!(
        unwaived.is_empty(),
        "src/ must be detlint-clean; unwaived: {:?}",
        unwaived
            .iter()
            .map(|f| format!("{} {}:{}", f.rule, f.file, f.line))
            .collect::<Vec<_>>()
    );
    // The only sanctioned wall-clock reads outside server/logging are the
    // phase-profiling and bench/runtime timers, each carrying a waiver.
    let (r2_total, r2_waived, r2_unwaived) = r.rule_counts("R2");
    assert!(r2_total >= 12, "the known profiler/bench/runtime timer sites");
    assert_eq!(r2_waived, r2_total);
    assert_eq!(r2_unwaived, 0);
    assert_eq!(r.rule_counts("R1"), (0, 0, 0), "no unordered iteration in the core");
    assert_eq!(r.rule_counts("R3"), (0, 0, 0), "no ambient randomness anywhere");
    assert_eq!(r.rule_counts("R5"), (0, 0, 0), "no malformed waivers");
    assert!(r.unused_waivers().is_empty(), "stale waivers are drift; remove them");
}

#[test]
fn cli_exit_codes_and_report_file() {
    let bin = env!("CARGO_BIN_EXE_detlint");
    let bad_report = std::env::temp_dir().join("detlint_selftest_bad.json");
    let out = std::process::Command::new(bin)
        .arg("--report")
        .arg(&bad_report)
        .arg(fixture("sim/r1_bad.rs"))
        .output()
        .expect("run detlint on a bad fixture");
    assert_eq!(out.status.code(), Some(1), "unwaived findings exit 1");
    let j = Json::parse(&std::fs::read_to_string(&bad_report).unwrap()).unwrap();
    assert_eq!(j.get("clean").unwrap().as_bool(), Some(false));
    assert_eq!(j.at(&["rules", "R1", "unwaived"]).unwrap().as_u64(), Some(6));
    let _ = std::fs::remove_file(&bad_report);

    let clean_report = std::env::temp_dir().join("detlint_selftest_clean.json");
    let out = std::process::Command::new(bin)
        .arg("--report")
        .arg(&clean_report)
        .arg("--quiet")
        .arg(fixture("sim/r1_clean.rs"))
        .output()
        .expect("run detlint on a clean fixture");
    assert_eq!(out.status.code(), Some(0), "clean tree exits 0");
    let j = Json::parse(&std::fs::read_to_string(&clean_report).unwrap()).unwrap();
    assert_eq!(j.get("clean").unwrap().as_bool(), Some(true));
    let _ = std::fs::remove_file(&clean_report);

    let out = std::process::Command::new(bin)
        .output()
        .expect("run detlint with no paths");
    assert_eq!(out.status.code(), Some(2), "usage error exits 2");
}
