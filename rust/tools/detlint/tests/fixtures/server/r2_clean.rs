// Fixture: the server scope is allowlisted for wall-clock reads
// (0 findings).

use std::time::Instant;

pub fn request_timer() -> u64 {
    let t0 = Instant::now();
    t0.elapsed().subsec_nanos() as u64
}
