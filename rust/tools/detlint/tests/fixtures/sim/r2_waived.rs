// Fixture: both waiver placements — standalone line covering the line
// below, and a trailing comment covering its own line (2 findings, both
// waived).

use std::time::Instant;

pub fn profile_block() -> u64 {
    // detlint:allow(R2) -- fixture: phase profiler wall-clock, write-only
    let t0 = Instant::now();
    let t1 = Instant::now(); // detlint:allow(R2) -- fixture: same timer pair
    t1.duration_since(t0).subsec_nanos() as u64
}
