// Fixture: a justified waiver covering an unordered iteration (1 finding,
// waived).

use std::collections::HashMap;

pub fn total(counts: &HashMap<u64, u64>) -> u64 {
    let mut acc = 0u64;
    // detlint:allow(R1) -- u64 addition is commutative; order cannot leak
    for v in counts.values() {
        acc = acc.wrapping_add(*v);
    }
    acc
}
