// Fixture: a well-formed waiver with a real justification (1 finding,
// waived).

use std::time::Instant;

pub fn good_waiver() -> u64 {
    // detlint:allow(R2) -- fixture: demonstrates the valid waiver grammar
    let t0 = Instant::now();
    t0.elapsed().subsec_nanos() as u64
}
