// Fixture: ordered iteration and non-iterating HashMap use are fine
// (0 findings).

use std::collections::{BTreeMap, HashMap};

pub fn sum_ordered(m: &BTreeMap<u64, u64>) -> u64 {
    let mut acc = 0u64;
    for (_, v) in m.iter() {
        acc = acc.wrapping_add(*v);
    }
    acc
}

pub fn lookup(cache: &mut HashMap<u64, u64>, k: u64) -> u64 {
    let hit = cache.get(&k).copied().unwrap_or(0);
    cache.insert(k, hit + 1);
    cache.len() as u64
}
