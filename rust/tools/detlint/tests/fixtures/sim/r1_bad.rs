// Fixture: unordered-container iteration in the deterministic core.
// Every iteration site below must trip R1 (6 findings).

use std::collections::{HashMap, HashSet};

pub struct State {
    pub index: HashMap<u64, u64>,
    pub seen: HashSet<u64>,
}

impl State {
    pub fn churn(&mut self) -> u64 {
        let mut acc = 0u64;
        for (k, v) in self.index.iter() {
            acc = acc.wrapping_add(k ^ v);
        }
        for k in self.index.keys() {
            acc = acc.wrapping_add(*k);
        }
        for v in self.index.values() {
            acc = acc.wrapping_add(*v);
        }
        for x in &self.seen {
            acc = acc.wrapping_add(*x);
        }
        self.seen.retain(|x| x % 2 == 0);
        self.index.drain();
        acc
    }
}
