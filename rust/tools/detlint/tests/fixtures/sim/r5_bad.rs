// Fixture: malformed waivers are findings themselves (2 × R5) and waive
// nothing, so the wall-clock reads stay unwaived too (2 × R2).

use std::time::Instant;

pub fn bad_waivers() -> u64 {
    // detlint:allow(R2)
    let t0 = Instant::now();
    // detlint:allow(R9) -- R9 is not a rule in the book
    let t1 = Instant::now();
    t1.duration_since(t0).subsec_nanos() as u64
}
