// Fixture: wall-clock reads in the deterministic core (2 findings).

use std::time::{Instant, SystemTime};

pub fn stamp() -> u128 {
    let t0 = Instant::now();
    let wall = SystemTime::now();
    let _ = wall;
    t0.elapsed().as_nanos()
}
