// Fixture: nothing to waive, nothing to find (0 findings, 0 waivers).

pub fn pure(a: u64, b: u64) -> u64 {
    a.wrapping_mul(31).wrapping_add(b)
}
