// Fixture: banned tokens inside literals and comments must not trip
// (0 findings). A real stray Instant::now() would, but this comment must
// not, and neither must any of the masked occurrences below.

pub fn masked() -> String {
    let s = "Instant::now() thread_rng HashMap";
    let raw = r#"SystemTime::now "from_entropy" RandomState"#;
    let c = 'r';
    /* block comment: OsRng rand::random getrandom */
    format!("{s}{raw}{c}")
}
