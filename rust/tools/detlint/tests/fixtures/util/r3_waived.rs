// Fixture: a waived ambient-randomness site (1 finding, waived).

pub fn jitter_seed() -> u64 {
    // detlint:allow(R3) -- fixture: nondeterministic jitter is the point here
    let x = rand::thread_rng().next_u64();
    x
}
