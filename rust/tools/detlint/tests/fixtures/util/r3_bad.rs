// Fixture: ambient randomness, banned tree-wide (3 findings).

pub fn entropy_soup() -> u64 {
    let mut rng = rand::thread_rng();
    let fast = SmallRng::from_entropy();
    let hasher = RandomState::new();
    seed_of(&mut rng, &fast, &hasher)
}
