// Fixture: all randomness derives from the seeded in-tree streams
// (0 findings).

use hiku::util::rng::Pcg64;

pub fn draw(seed: u64) -> u64 {
    let mut rng = Pcg64::new(seed);
    rng.next_u64()
}
