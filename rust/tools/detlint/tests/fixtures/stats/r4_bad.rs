// Fixture: float accumulation over unordered iteration in a metrics merge
// path. One site, two findings: R1 (unordered iteration) and R4 (order-
// sensitive f64 accumulation).

use std::collections::HashMap;

pub fn merge_mean(bins: &HashMap<u64, f64>) -> f64 {
    let mut total = 0.0f64;
    for v in bins.values() {
        total += *v;
    }
    total
}
