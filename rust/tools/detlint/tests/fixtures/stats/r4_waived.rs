// Fixture: one waiver naming two rules covers both findings on the line
// below (R1 + R4, both waived).

use std::collections::HashMap;

pub fn merge_sum(bins: &HashMap<u64, f64>) -> f64 {
    let mut total = 0.0f64;
    // detlint:allow(R1,R4) -- fixture: merge proven order-insensitive by test
    for v in bins.values() {
        total += *v;
    }
    total
}
