// Fixture: ordered merge — the deterministic way to accumulate floats
// (0 findings).

use std::collections::BTreeMap;

pub fn merge_mean(bins: &BTreeMap<u64, f64>) -> f64 {
    let mut total = 0.0f64;
    for v in bins.values() {
        total += *v;
    }
    total
}
