//! Aggregated scan results and the `detlint_report.json` schema.
//!
//! The JSON report is the machine-readable contract consumed by CI (the
//! `rust-detlint` job uploads it as an artifact) and by EXPERIMENTS.md
//! readers auditing the waiver inventory. It is rendered through
//! `hiku::util::json` — objects are BTreeMap-backed, so the byte output is
//! a pure function of the scan results.

use crate::rules::{Finding, Waiver, RULES};
use hiku::util::json::{obj, Json};

/// The result of scanning a set of roots.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Root paths as passed on the command line.
    pub roots: Vec<String>,
    /// Number of `.rs` files scanned.
    pub files: usize,
    /// Total physical lines scanned.
    pub lines: usize,
    /// Every finding, waived or not, sorted by (file, line, rule).
    pub findings: Vec<Finding>,
    /// Every well-formed waiver encountered, sorted by (file, line).
    pub waivers: Vec<Waiver>,
}

impl Report {
    /// (total, waived, unwaived) counts for one rule.
    pub fn rule_counts(&self, rule: &str) -> (usize, usize, usize) {
        let total = self.findings.iter().filter(|f| f.rule == rule).count();
        let waived = self.findings.iter().filter(|f| f.rule == rule && f.waived).count();
        (total, waived, total - waived)
    }

    /// Findings not covered by a waiver — the failure set.
    pub fn unwaived(&self) -> Vec<&Finding> {
        self.findings.iter().filter(|f| !f.waived).collect()
    }

    /// True when nothing unwaived remains (exit code 0).
    pub fn clean(&self) -> bool {
        self.findings.iter().all(|f| f.waived)
    }

    /// Waivers no finding consumed. Reported (not failing): an unused
    /// waiver means the code it excused was fixed or moved, and the
    /// comment is now drift to clean up.
    pub fn unused_waivers(&self) -> Vec<&Waiver> {
        self.waivers.iter().filter(|w| !w.used).collect()
    }

    /// Build the `detlint_report.json` document.
    pub fn to_json(&self) -> Json {
        let rules = RULES
            .iter()
            .map(|r| {
                let (total, waived, unwaived) = self.rule_counts(r);
                (
                    *r,
                    obj(vec![
                        ("total", total.into()),
                        ("waived", waived.into()),
                        ("unwaived", unwaived.into()),
                    ]),
                )
            })
            .collect::<Vec<_>>();
        let findings = self
            .findings
            .iter()
            .map(|f| {
                let mut pairs = vec![
                    ("rule", f.rule.into()),
                    ("file", f.file.as_str().into()),
                    ("line", f.line.into()),
                    ("message", f.message.as_str().into()),
                    ("snippet", f.snippet.as_str().into()),
                    ("waived", f.waived.into()),
                ];
                if f.waived {
                    pairs.push(("justification", f.justification.as_str().into()));
                }
                obj(pairs)
            })
            .collect::<Vec<Json>>();
        let unused = self
            .unused_waivers()
            .iter()
            .map(|w| {
                obj(vec![("file", w.file.as_str().into()), ("line", w.line.into())])
            })
            .collect::<Vec<Json>>();
        obj(vec![
            ("version", 1u64.into()),
            ("tool", "detlint".into()),
            (
                "roots",
                Json::Arr(self.roots.iter().map(|r| r.as_str().into()).collect()),
            ),
            ("files_scanned", self.files.into()),
            ("lines_scanned", self.lines.into()),
            ("clean", self.clean().into()),
            ("rules", obj(rules)),
            (
                "waivers",
                obj(vec![
                    ("valid", self.waivers.len().into()),
                    (
                        "used",
                        self.waivers.iter().filter(|w| w.used).count().into(),
                    ),
                    ("unused", Json::Arr(unused)),
                ]),
            ),
            ("findings", Json::Arr(findings)),
        ])
    }

    /// Human-readable rendering for the terminal.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            if f.waived {
                out.push_str(&format!(
                    "waived  {} {}:{} {} ({})\n",
                    f.rule, f.file, f.line, f.message, f.justification
                ));
            } else {
                out.push_str(&format!(
                    "FAIL    {} {}:{} {}\n        {}\n",
                    f.rule, f.file, f.line, f.message, f.snippet
                ));
            }
        }
        for w in self.unused_waivers() {
            out.push_str(&format!(
                "unused  waiver at {}:{} ({}) — no finding consumed it; remove the comment\n",
                w.file,
                w.line,
                w.rules.join(",")
            ));
        }
        let mut counts = Vec::new();
        for r in RULES {
            let (total, waived, _) = self.rule_counts(r);
            if total > 0 {
                counts.push(format!("{r} {total} ({waived} waived)"));
            }
        }
        let summary =
            if counts.is_empty() { "no findings".to_string() } else { counts.join(", ") };
        let unwaived = self.unwaived().len();
        out.push_str(&format!(
            "detlint: {} files, {} lines scanned; {summary}; {unwaived} unwaived finding(s)\n",
            self.files, self.lines
        ));
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn finding(rule: &'static str, waived: bool) -> Finding {
        Finding {
            rule,
            file: "f.rs".to_string(),
            line: 3,
            message: "m".to_string(),
            snippet: "s".to_string(),
            waived,
            justification: if waived { "because tested".to_string() } else { String::new() },
        }
    }

    #[test]
    fn counts_and_clean() {
        let mut r = Report::default();
        assert!(r.clean());
        r.findings.push(finding("R1", true));
        r.findings.push(finding("R1", false));
        r.findings.push(finding("R3", false));
        assert_eq!(r.rule_counts("R1"), (2, 1, 1));
        assert_eq!(r.rule_counts("R2"), (0, 0, 0));
        assert_eq!(r.unwaived().len(), 2);
        assert!(!r.clean());
    }

    #[test]
    fn json_shape_is_stable_and_parseable() {
        let mut r = Report {
            roots: vec!["src".to_string()],
            files: 2,
            lines: 40,
            ..Report::default()
        };
        r.findings.push(finding("R2", true));
        let text = r.to_json().to_string_pretty();
        let j = Json::parse(&text).expect("report must round-trip through the parser");
        assert_eq!(j.get("version").unwrap().as_u64(), Some(1));
        assert_eq!(j.get("tool").unwrap().as_str(), Some("detlint"));
        assert_eq!(j.get("clean").unwrap().as_bool(), Some(true));
        assert_eq!(j.at(&["rules", "R2", "waived"]).unwrap().as_u64(), Some(1));
        assert_eq!(j.at(&["rules", "R5", "total"]).unwrap().as_u64(), Some(0));
        assert_eq!(
            j.at(&["findings", "0", "justification"]).unwrap().as_str(),
            Some("because tested")
        );
        // Unwaived findings must not carry a justification key.
        let mut r2 = Report::default();
        r2.findings.push(finding("R1", false));
        let j2 = Json::parse(&r2.to_json().to_string_pretty()).unwrap();
        assert!(j2.at(&["findings", "0", "justification"]).is_none());
        assert_eq!(j2.get("clean").unwrap().as_bool(), Some(false));
    }
}
