//! detlint — determinism & data-race static analysis for the hiku core.
//!
//! Enforces the determinism rulebook of DESIGN.md §12 over the Rust source
//! tree: no unordered-container iteration in the deterministic core (R1),
//! no wall-clock reads outside the allowlist (R2), no ambient randomness
//! (R3), no float accumulation over unordered iteration in metrics merge
//! paths (R4), and a counted, justified waiver grammar (R5). Run it as
//!
//! ```text
//! cargo run -p detlint -- src
//! ```
//!
//! from `rust/` (CI runs exactly this and uploads `detlint_report.json`).
//! The lint is static and heuristic; the nightly ThreadSanitizer and Miri
//! CI jobs are its dynamic complement (see EXPERIMENTS.md).

#![forbid(unsafe_code)]
#![warn(missing_docs)]

pub mod lexer;
pub mod report;
pub mod rules;

use report::Report;
use std::io;
use std::path::{Path, PathBuf};

/// Scan one already-loaded source file into `report`.
pub fn scan_source(path: &str, src: &str, report: &mut Report) {
    let (findings, waivers, lines) = rules::scan_file(path, src);
    report.files += 1;
    report.lines += lines;
    report.findings.extend(findings);
    report.waivers.extend(waivers);
}

/// Scan every `.rs` file under the given roots (files are accepted too).
/// The walk order, finding order, and waiver order are all sorted, so the
/// report bytes are a pure function of the tree contents.
pub fn scan_paths(roots: &[PathBuf]) -> io::Result<Report> {
    let mut files: Vec<PathBuf> = Vec::new();
    for root in roots {
        collect_rs(root, &mut files)?;
    }
    files.sort();
    files.dedup();
    let mut report = Report {
        roots: roots.iter().map(|r| r.display().to_string()).collect(),
        ..Report::default()
    };
    for file in &files {
        let src = std::fs::read_to_string(file)?;
        scan_source(&file.display().to_string(), &src, &mut report);
    }
    report
        .findings
        .sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    report.waivers.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(report)
}

/// Recursively gather `.rs` files, skipping `target/` and dot-directories.
fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = std::fs::metadata(path)?;
    if meta.is_file() {
        if path.extension().is_some_and(|e| e == "rs") {
            out.push(path.to_path_buf());
        }
        return Ok(());
    }
    let mut entries: Vec<PathBuf> =
        std::fs::read_dir(path)?.map(|e| e.map(|e| e.path())).collect::<io::Result<_>>()?;
    entries.sort();
    for entry in entries {
        let name = entry.file_name().and_then(|n| n.to_str()).unwrap_or("");
        if name == "target" || name.starts_with('.') {
            continue;
        }
        if entry.is_dir() {
            collect_rs(&entry, out)?;
        } else if entry.extension().is_some_and(|e| e == "rs") {
            out.push(entry);
        }
    }
    Ok(())
}
