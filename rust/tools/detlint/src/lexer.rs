//! Line-level source splitter for the rule scanners.
//!
//! `detlint` is deliberately not a full Rust parser (no `syn` is vendored
//! in this image): every rule in the determinism rulebook (DESIGN.md §12)
//! is expressible as a token match over *code* text, provided literals and
//! comments cannot alias tokens. This module does exactly that separation:
//! each physical line is split into a `code` half — with string and char
//! literal *contents* blanked but their delimiters kept — and a `comment`
//! half that [`crate::rules`] reads for `detlint:allow` waivers.
//!
//! Handled: line comments (`//`, `///`, `//!`), nested block comments,
//! plain strings with escapes (including `\`-continued and raw-newline
//! multi-line strings), byte strings, raw strings `r"…"` / `r#"…"#` (any
//! hash depth, `br` too), char literals (escape and plain form), and the
//! char-literal-vs-lifetime ambiguity (`'a'` vs `<'a>` / `&'static`).

/// One physical source line, split into scannable halves.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct Line {
    /// Code text with string/char-literal contents blanked (delimiters kept).
    pub code: String,
    /// Comment text on the line, including the `//` / `/*` markers.
    pub comment: String,
}

/// Is `c` an identifier character (`[A-Za-z0-9_]`)?
pub fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Byte-level twin of [`is_ident`] for token boundary checks.
pub fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

/// Lexer state that survives newlines.
enum St {
    /// Ordinary code.
    Code,
    /// Inside a block comment at the given nesting depth (Rust block
    /// comments nest).
    Block(usize),
    /// Inside a plain (or byte) string literal.
    Str,
    /// Inside a raw string literal opened with this many `#`s.
    RawStr(usize),
}

/// Split `src` into per-line code/comment halves. Line numbering is
/// 1-based in the scanners: `lines[i]` is source line `i + 1`.
pub fn split_lines(src: &str) -> Vec<Line> {
    let cs: Vec<char> = src.chars().collect();
    let n = cs.len();
    let mut lines = Vec::new();
    let mut cur = Line::default();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < n {
        let c = cs[i];
        if c == '\n' {
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            St::Block(depth) => {
                if c == '/' && cs.get(i + 1) == Some(&'*') {
                    st = St::Block(depth + 1);
                    cur.comment.push_str("/*");
                    i += 2;
                } else if c == '*' && cs.get(i + 1) == Some(&'/') {
                    st = if depth == 1 { St::Code } else { St::Block(depth - 1) };
                    cur.comment.push_str("*/");
                    i += 2;
                } else {
                    cur.comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    if cs.get(i + 1) == Some(&'\n') {
                        // `\`-continued string: the physical line still ends.
                        lines.push(std::mem::take(&mut cur));
                    }
                    i += 2; // skip the escaped character
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            St::RawStr(hashes) => {
                if c == '"' && closes_raw(&cs, i + 1, hashes) {
                    cur.code.push('"');
                    st = St::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
            St::Code => {
                if c == '/' && cs.get(i + 1) == Some(&'/') {
                    while i < n && cs[i] != '\n' {
                        cur.comment.push(cs[i]);
                        i += 1;
                    }
                } else if c == '/' && cs.get(i + 1) == Some(&'*') {
                    st = St::Block(1);
                    cur.comment.push_str("/*");
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = St::Str;
                    i += 1;
                } else if let Some((skip, hashes)) = raw_str_intro(&cs, i, &cur.code) {
                    cur.code.push_str("r\"");
                    st = St::RawStr(hashes);
                    i += skip;
                } else if c == '\'' {
                    i = consume_quote(&cs, i, &mut cur.code);
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
        }
    }
    if !cur.code.is_empty() || !cur.comment.is_empty() {
        lines.push(cur);
    }
    lines
}

/// Does `hashes`-many `#`s follow position `from`? (Raw string closer.)
fn closes_raw(cs: &[char], from: usize, hashes: usize) -> bool {
    (0..hashes).all(|k| cs.get(from + k) == Some(&'#'))
}

/// Match a raw-string opener `[b]r#*"` at `i`. The char before must not be
/// an identifier character (so the `r` in `for` never opens a string).
/// Returns (chars consumed, hash depth).
fn raw_str_intro(cs: &[char], i: usize, code_so_far: &str) -> Option<(usize, usize)> {
    if code_so_far.chars().last().is_some_and(is_ident) {
        return None;
    }
    let mut j = i;
    if cs.get(j) == Some(&'b') {
        j += 1;
    }
    if cs.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0usize;
    while cs.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if cs.get(j) != Some(&'"') {
        return None;
    }
    Some((j + 1 - i, hashes))
}

/// Consume a `'` at position `i`: a char literal is blanked to `' '`, a
/// lifetime keeps its quote. Returns the position after the consumed text.
fn consume_quote(cs: &[char], i: usize, code: &mut String) -> usize {
    let n = cs.len();
    if cs.get(i + 1) == Some(&'\\') {
        // Escape form: '\n', '\'', '\u{…}' — scan to the closing quote.
        code.push_str("' '");
        let mut j = i + 1;
        while j < n && cs[j] != '\n' {
            if cs[j] == '\\' {
                j += 2;
                continue;
            }
            if cs[j] == '\'' {
                j += 1;
                break;
            }
            j += 1;
        }
        j
    } else if i + 2 < n && cs[i + 2] == '\'' && cs[i + 1] != '\n' {
        // Plain form 'x'.
        code.push_str("' '");
        i + 3
    } else {
        // Lifetime ('a, 'static) or stray quote.
        code.push('\'');
        i + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        split_lines(src).into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn comments_are_separated_from_code() {
        let lines = split_lines("let x = 1; // trailing note\n// full line\nlet y = 2;\n");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[0].code.trim(), "let x = 1;");
        assert_eq!(lines[0].comment, "// trailing note");
        assert_eq!(lines[1].code, "");
        assert_eq!(lines[1].comment, "// full line");
        assert_eq!(lines[2].comment, "");
    }

    #[test]
    fn string_contents_are_blanked() {
        let c = codes("let s = \"Instant::now() HashMap\";\n");
        assert_eq!(c[0], "let s = \"\";");
    }

    #[test]
    fn raw_strings_are_blanked() {
        let c = codes("let s = r#\"thread_rng \"quoted\" inside\"#;\n");
        assert_eq!(c[0], "let s = r\"\";");
        // Unbalanced quote inside the raw string must not leak state.
        let c = codes("let s = r\"SystemTime::now\"; let t = 1;\n");
        assert_eq!(c[0], "let s = r\"\"; let t = 1;");
    }

    #[test]
    fn multi_line_string_keeps_line_count() {
        let lines = split_lines("let s = \"a\nb\";\nlet x = 1;\n");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[2].code, "let x = 1;");
    }

    #[test]
    fn char_literal_vs_lifetime() {
        let c = codes("let c = 'r'; let d: &'static str = x; let e = '\\'';\n");
        assert_eq!(c[0], "let c = ' '; let d: &'static str = x; let e = ' ';");
    }

    #[test]
    fn nested_block_comments() {
        let lines = split_lines("a /* one /* two */ still */ b\n");
        assert_eq!(lines[0].code.replace(' ', ""), "ab");
        assert!(lines[0].comment.contains("two"));
    }

    #[test]
    fn block_comment_spans_lines() {
        let lines = split_lines("x /* start\nmiddle Instant::now()\nend */ y\n");
        assert_eq!(lines.len(), 3);
        assert_eq!(lines[1].code, "");
        assert!(lines[1].comment.contains("Instant::now"));
        assert_eq!(lines[2].code.trim(), "y");
    }

    #[test]
    fn raw_intro_requires_non_ident_boundary() {
        // The `r` in `for` must not open a raw string.
        let c = codes("for x in xs { f(x) }\n");
        assert_eq!(c[0], "for x in xs { f(x) }");
    }
}
