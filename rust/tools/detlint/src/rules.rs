//! The determinism rulebook (R1–R5) as token-level checks.
//!
//! Every headline number this reproduction reports — cold-start ratios,
//! load-balance gains, bit-identity per (seed, shards) — rests on the
//! determinism rules that DESIGN.md §12 writes down. This module enforces
//! them mechanically over [`crate::lexer`] output:
//!
//! - **R1** — no `HashMap`/`HashSet` (or `BinaryHeap`) *iteration*
//!   (`iter`/`keys`/`values`/`into_iter`/`drain`/`retain`/for-loops) in
//!   the deterministic core. Map iteration order must come from `BTreeMap`
//!   or an explicit sort.
//! - **R2** — no `Instant::now`/`SystemTime::now` outside the wall-clock
//!   allowlist (`server/`, `logging.rs`). Phase-profiling timers in the
//!   sim engine carry inline waivers instead, so every site is visible in
//!   the report.
//! - **R3** — no ambient randomness anywhere (`thread_rng`,
//!   `from_entropy`, `OsRng`, `getrandom`, `RandomState`, `rand::random`);
//!   all RNG derives from `util/rng` seeded streams.
//! - **R4** — no `f64` accumulation over unordered iteration in the
//!   metrics merge paths (`stats.rs`, `metrics.rs`, `report/`): float
//!   addition does not commute in rounding, so unordered sums break
//!   bit-identity even when the set of addends is fixed.
//! - **R5** — every waiver is `// detlint:allow(<rules>) -- <reason>`;
//!   a malformed waiver (bad grammar, unknown rule, missing or trivial
//!   justification) is itself a finding and waives nothing.
//!
//! The checks are heuristic by design (no type inference): container
//! bindings are tracked per file from `name: HashMap<…>` ascriptions and
//! `name = HashMap::new()` initializers, and iteration is matched against
//! those names. A binding the heuristic cannot see escapes R1/R4 — the
//! nightly TSan/Miri jobs are the dynamic backstop — but a finding it
//! *does* report is precise enough to act on.

use crate::lexer::{is_ident, is_ident_byte, split_lines, Line};
use std::collections::BTreeMap;

/// All rule identifiers, in report order.
pub const RULES: [&str; 5] = ["R1", "R2", "R3", "R4", "R5"];

/// Rules a waiver may name. R5 findings are about waivers themselves and
/// cannot be waived away.
pub const WAIVABLE: [&str; 4] = ["R1", "R2", "R3", "R4"];

/// The waiver marker scanned for inside comments.
pub const WAIVER_MARK: &str = "detlint:allow";

/// Unordered containers whose iteration order is not a pure function of
/// the inserted data.
const UNORDERED: [&str; 3] = ["HashMap", "HashSet", "BinaryHeap"];

/// Iteration-shaped methods on the tracked containers.
const ITER_METHODS: [&str; 11] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "into_iter",
    "into_keys",
    "into_values",
    "drain",
    "retain",
    "extract_if",
];

/// Wall-clock tokens (R2).
const R2_TOKENS: [&str; 2] = ["Instant::now", "SystemTime::now"];

/// Ambient-randomness tokens (R3).
const R3_TOKENS: [&str; 6] = [
    "thread_rng",
    "from_entropy",
    "OsRng",
    "getrandom",
    "RandomState",
    "rand::random",
];

/// Accumulation markers that upgrade an unordered iteration to R4 when
/// found within three lines of the iteration site.
const R4_ACCUM: [&str; 5] = ["+=", ".sum(", ".sum::<", ".fold(", ".product("];

/// One diagnostic.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Rule id (`"R1"`..`"R5"`).
    pub rule: &'static str,
    /// Path as given to the scanner.
    pub file: String,
    /// 1-based line number.
    pub line: usize,
    /// Human-readable description.
    pub message: String,
    /// Trimmed code (or comment text, for R5) from the offending line.
    pub snippet: String,
    /// True when covered by a valid `detlint:allow` waiver.
    pub waived: bool,
    /// The covering waiver's justification (empty when unwaived).
    pub justification: String,
}

/// A parsed, well-formed waiver comment.
#[derive(Clone, Debug)]
pub struct Waiver {
    /// Path of the file the waiver sits in.
    pub file: String,
    /// 1-based line of the comment.
    pub line: usize,
    /// Rules it waives.
    pub rules: Vec<String>,
    /// Text after `--`.
    pub justification: String,
    /// Set once a finding consumes it (an unused waiver is drift).
    pub used: bool,
    /// True when the comment is the only thing on its line, in which case
    /// it covers the next line instead of its own.
    pub standalone: bool,
}

/// Which rule families apply to a file, derived from its module path.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Scope {
    /// R1 (unordered iteration) applies: the deterministic core.
    pub r1: bool,
    /// R2 wall-clock reads are allowlisted here (`server/`, `logging.rs`).
    pub r2_allowed: bool,
    /// R4 (metrics merge float accumulation) applies.
    pub r4: bool,
}

/// Classify `path` into rule scopes.
///
/// The module-relative path is whatever follows the last `src/` (or, for
/// the self-test fixtures, `fixtures/`) component; its first segment —
/// with any `.rs` suffix stripped — picks the scope:
///
/// - wall-clock-native modules (`server`, `runtime`, `logging`, `bench`,
///   `main`) are exempt from R1; of those, only `server` and `logging`
///   are also allowlisted for R2 (the runtime and the bench harness keep
///   per-site waivers so their timers stay visible in the report);
/// - `stats`, `metrics`, `report` are the metrics merge paths (R4);
/// - everything else is deterministic core: R1 applies, R2 needs waivers.
pub fn classify(path: &str) -> Scope {
    let norm = path.replace('\\', "/");
    let rel = if let Some((_, r)) = norm.rsplit_once("src/") {
        r.to_string()
    } else if let Some((_, r)) = norm.rsplit_once("fixtures/") {
        r.to_string()
    } else if let Some((_, f)) = norm.rsplit_once('/') {
        f.to_string()
    } else {
        norm
    };
    let first = rel.split('/').next().unwrap_or("");
    let first = first.strip_suffix(".rs").unwrap_or(first);
    let wall_clock_native = matches!(first, "server" | "runtime" | "logging" | "bench" | "main");
    Scope {
        r1: !wall_clock_native,
        r2_allowed: matches!(first, "server" | "logging"),
        r4: matches!(first, "stats" | "metrics" | "report"),
    }
}

/// Scan one file's source. Returns (findings, waivers, line count).
/// Findings are in line order; waiver application has already run.
pub fn scan_file(path: &str, src: &str) -> (Vec<Finding>, Vec<Waiver>, usize) {
    let lines = split_lines(src);
    let scope = classify(path);
    let mut findings: Vec<Finding> = Vec::new();
    let mut waivers: Vec<Waiver> = Vec::new();

    // Pass 1: waivers (and R5 findings for malformed ones).
    for (idx, ln) in lines.iter().enumerate() {
        let lineno = idx + 1;
        match parse_waiver(&ln.comment) {
            None => {}
            Some(Ok((rules, justification))) => waivers.push(Waiver {
                file: path.to_string(),
                line: lineno,
                rules,
                justification,
                used: false,
                standalone: ln.code.trim().is_empty(),
            }),
            Some(Err(msg)) => findings.push(Finding {
                rule: "R5",
                file: path.to_string(),
                line: lineno,
                message: msg,
                snippet: snip(ln.comment.trim()),
                waived: false,
                justification: String::new(),
            }),
        }
    }

    // Pass 2: container bindings, whole file (fields bind before methods).
    let mut bindings: BTreeMap<String, &'static str> = BTreeMap::new();
    for ln in &lines {
        collect_bindings(&ln.code, &mut bindings);
    }

    // Pass 3: per-line rule checks.
    for (idx, ln) in lines.iter().enumerate() {
        let lineno = idx + 1;
        let code = &ln.code;
        let mut push = |rule: &'static str, message: String| {
            findings.push(Finding {
                rule,
                file: path.to_string(),
                line: lineno,
                message,
                snippet: snip(code.trim()),
                waived: false,
                justification: String::new(),
            });
        };

        // R1 / R4: iteration over tracked unordered containers.
        let mut iters: Vec<(String, String)> = iter_calls(code);
        if let Some(recv) = for_loop_receiver(code) {
            iters.push((recv, "for-loop".to_string()));
        }
        for (recv, how) in iters {
            let Some(kind) = bindings.get(recv.as_str()).copied() else { continue };
            if scope.r1 {
                push(
                    "R1",
                    format!(
                        "{kind} iteration via {how} on `{recv}`: unordered iteration in the \
                         deterministic core (use BTreeMap/BTreeSet or sort first)"
                    ),
                );
            }
            if scope.r4 {
                let window: Vec<&str> = lines[idx..(idx + 3).min(lines.len())]
                    .iter()
                    .map(|l| l.code.as_str())
                    .collect();
                let window = window.join("\n");
                if R4_ACCUM.iter().any(|m| window.contains(m)) {
                    push(
                        "R4",
                        format!(
                            "f64 accumulation over unordered {kind} iteration on `{recv}` in a \
                             metrics merge path: float addition is order-sensitive in rounding"
                        ),
                    );
                }
            }
        }

        // R2: wall-clock reads outside the allowlist.
        if !scope.r2_allowed {
            for tok in R2_TOKENS {
                for _ in 0..count_tokens(code, tok) {
                    push(
                        "R2",
                        format!(
                            "`{tok}` outside the wall-clock allowlist (server/, logging.rs): \
                             sim-path time must be virtual"
                        ),
                    );
                }
            }
        }

        // R3: ambient randomness, banned tree-wide.
        for tok in R3_TOKENS {
            for _ in 0..count_tokens(code, tok) {
                push(
                    "R3",
                    format!(
                        "`{tok}`: ambient randomness; derive all RNG from util/rng seeded streams"
                    ),
                );
            }
        }
    }

    // Pass 4: apply waivers. A waiver covers findings on its own line, or
    // — when it is a standalone comment — on the line directly below.
    for f in &mut findings {
        if f.rule == "R5" {
            continue;
        }
        for w in &mut waivers {
            if !w.rules.iter().any(|r| r == f.rule) {
                continue;
            }
            if w.line == f.line || (w.standalone && w.line + 1 == f.line) {
                f.waived = true;
                f.justification = w.justification.clone();
                w.used = true;
                break;
            }
        }
    }

    (findings, waivers, lines.len())
}

/// Truncate a snippet to a bounded width for the report.
fn snip(s: &str) -> String {
    const MAX: usize = 160;
    if s.chars().count() <= MAX {
        s.to_string()
    } else {
        let cut: String = s.chars().take(MAX).collect();
        format!("{cut}…")
    }
}

/// Parse a waiver out of a comment. `None`: no marker present. `Some(Err)`:
/// marker present but malformed (an R5 finding). `Some(Ok)`: rules + reason.
pub fn parse_waiver(comment: &str) -> Option<Result<(Vec<String>, String), String>> {
    let p = comment.find(WAIVER_MARK)?;
    let rest = comment[p + WAIVER_MARK.len()..].trim_start();
    let Some(rest) = rest.strip_prefix('(') else {
        return Some(Err(format!("waiver is missing '(<rules>)' after {WAIVER_MARK}")));
    };
    let Some(close) = rest.find(')') else {
        return Some(Err("waiver rule list is missing the closing ')'".to_string()));
    };
    let mut rules = Vec::new();
    for raw in rest[..close].split(',') {
        let r = raw.trim().to_string();
        if !WAIVABLE.contains(&r.as_str()) {
            return Some(Err(format!("waiver names unknown or unwaivable rule '{r}'")));
        }
        rules.push(r);
    }
    let tail = rest[close + 1..].trim_start();
    let Some(just) = tail.strip_prefix("--") else {
        return Some(Err("waiver is missing '-- <justification>'".to_string()));
    };
    let just = just.trim().to_string();
    if just.len() < 8 {
        return Some(Err(
            "waiver justification is missing or too short (min 8 chars)".to_string(),
        ));
    }
    Some(Ok((rules, just)))
}

/// Count whole-token occurrences of `tok` in `code` (neighbors must not be
/// identifier characters, so `rand::random` does not match `random_range`).
fn count_tokens(code: &str, tok: &str) -> usize {
    let bytes = code.as_bytes();
    let mut n = 0usize;
    let mut from = 0usize;
    while let Some(p) = code[from..].find(tok) {
        let at = from + p;
        let end = at + tok.len();
        let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
        let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            n += 1;
        }
        from = end;
    }
    n
}

/// Find `.method(` iteration calls and resolve each receiver's last path
/// segment (`self.index.iter()` → `index`). Chained-call receivers
/// (`f().iter()`) are unresolvable and skipped.
fn iter_calls(code: &str) -> Vec<(String, String)> {
    let mut out = Vec::new();
    for m in ITER_METHODS {
        let needle = format!(".{m}(");
        let mut from = 0usize;
        while let Some(p) = code[from..].find(needle.as_str()) {
            let at = from + p;
            if let Some(recv) = last_ident_before(code, at) {
                out.push((recv, format!(".{m}()")));
            }
            from = at + needle.len();
        }
    }
    out
}

/// `for <pat> in <expr> {`: when `<expr>` is a bare identifier chain
/// (optionally `&`/`&mut`-prefixed), return its last segment. Method-call
/// expressions are left to [`iter_calls`] so nothing double-counts.
fn for_loop_receiver(code: &str) -> Option<String> {
    let t = code.trim_start();
    if !t.starts_with("for ") {
        return None;
    }
    let pos = t.find(" in ")?;
    let mut expr = t[pos + 4..].trim();
    if let Some(brace) = expr.find('{') {
        expr = expr[..brace].trim();
    }
    while let Some(rest) = expr.strip_prefix('&') {
        expr = rest.trim_start();
    }
    if let Some(rest) = expr.strip_prefix("mut ") {
        expr = rest.trim_start();
    }
    if expr.is_empty() || !expr.chars().all(|c| is_ident(c) || c == '.') {
        return None;
    }
    let last = expr.rsplit('.').next().unwrap_or("");
    if last.is_empty() || last.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(last.to_string())
}

/// The identifier immediately before byte position `at` (skipping spaces).
fn last_ident_before(code: &str, at: usize) -> Option<String> {
    let pre: Vec<char> = code[..at].chars().collect();
    let mut i = pre.len();
    while i > 0 && pre[i - 1].is_whitespace() {
        i -= 1;
    }
    let start = ident_start(&pre, i);
    if start == i {
        return None;
    }
    let s: String = pre[start..i].iter().collect();
    if s.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(s)
}

/// Start index of the identifier ending at `end` (== `end` when none).
fn ident_start(pre: &[char], end: usize) -> usize {
    let mut s = end;
    while s > 0 && is_ident(pre[s - 1]) {
        s -= 1;
    }
    s
}

/// Record container bindings on this line: `name: HashMap<…>` ascriptions
/// (let/field/param, through `&`/`mut` and path-qualified types) and
/// `name = HashMap::new()`-style initializers.
fn collect_bindings(code: &str, out: &mut BTreeMap<String, &'static str>) {
    let bytes = code.as_bytes();
    for kind in UNORDERED {
        let mut from = 0usize;
        while let Some(p) = code[from..].find(kind) {
            let at = from + p;
            let end = at + kind.len();
            let before_ok = at == 0 || !is_ident_byte(bytes[at - 1]);
            let after_ok = end >= bytes.len() || !is_ident_byte(bytes[end]);
            if before_ok && after_ok {
                if let Some(name) = binding_name_before(code, at) {
                    out.insert(name, kind);
                }
            }
            from = end;
        }
    }
}

/// Walk backwards from a container token to the identifier it is bound to,
/// through `&`, `mut`, `dyn`, and `path::` qualifiers. `None` when the
/// token is not in binding position (imports, return types, generics of a
/// wrapper type, enum payloads, …).
fn binding_name_before(code: &str, at: usize) -> Option<String> {
    let pre: Vec<char> = code[..at].chars().collect();
    let mut i = pre.len();
    loop {
        while i > 0 && pre[i - 1].is_whitespace() {
            i -= 1;
        }
        if i == 0 {
            return None;
        }
        let c = pre[i - 1];
        if c == '&' {
            i -= 1;
            continue;
        }
        if c == ':' {
            if i >= 2 && pre[i - 2] == ':' {
                // `::` path separator — step over it and its leading segment.
                i -= 2;
                while i > 0 && pre[i - 1].is_whitespace() {
                    i -= 1;
                }
                let s = ident_start(&pre, i);
                if s == i {
                    return None;
                }
                i = s;
                continue;
            }
            // Type-ascription colon: the name is the identifier before it.
            i -= 1;
            while i > 0 && pre[i - 1].is_whitespace() {
                i -= 1;
            }
            let s = ident_start(&pre, i);
            if s == i {
                return None;
            }
            return filter_name(pre[s..i].iter().collect());
        }
        if c == '=' {
            // Assignment — but not `==`, `=>` (seen as '>' first), `+=`, ….
            if i >= 2 && "=+-*/!<>&|^".contains(pre[i - 2]) {
                return None;
            }
            i -= 1;
            while i > 0 && pre[i - 1].is_whitespace() {
                i -= 1;
            }
            let s = ident_start(&pre, i);
            if s == i {
                return None;
            }
            return filter_name(pre[s..i].iter().collect());
        }
        if is_ident(c) {
            let s = ident_start(&pre, i);
            let word: String = pre[s..i].iter().collect();
            if word == "mut" || word == "dyn" || word == "ref" {
                i = s;
                continue;
            }
            return None;
        }
        return None;
    }
}

/// Reject keywords and digit-leading captures as binding names.
fn filter_name(name: String) -> Option<String> {
    const KEYWORDS: [&str; 8] = ["let", "mut", "in", "if", "fn", "impl", "use", "return"];
    if name.is_empty() || KEYWORDS.contains(&name.as_str()) {
        return None;
    }
    if name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        return None;
    }
    Some(name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn classify_maps_modules_to_scopes() {
        let core = classify("rust/src/sim/engine.rs");
        assert!(core.r1 && !core.r2_allowed && !core.r4);
        let server = classify("rust/src/server/mod.rs");
        assert!(!server.r1 && server.r2_allowed && !server.r4);
        let logging = classify("rust/src/logging.rs");
        assert!(!logging.r1 && logging.r2_allowed);
        let runtime = classify("rust/src/runtime/engine.rs");
        assert!(!runtime.r1 && !runtime.r2_allowed, "runtime timers need waivers");
        let stats = classify("rust/src/stats.rs");
        assert!(stats.r1 && stats.r4);
        let fixture = classify("tests/fixtures/sim/r1_bad.rs");
        assert!(fixture.r1 && !fixture.r2_allowed);
    }

    fn bindings_of(code: &str) -> BTreeMap<String, &'static str> {
        let mut b = BTreeMap::new();
        collect_bindings(code, &mut b);
        b
    }

    #[test]
    fn binding_extraction_positive_cases() {
        assert_eq!(bindings_of("pub index: HashMap<u64, u64>,").get("index"), Some(&"HashMap"));
        assert_eq!(bindings_of("fn f(m: &mut HashMap<K, V>) {}").get("m"), Some(&"HashMap"));
        assert_eq!(
            bindings_of("let seen = HashSet::new();").get("seen"),
            Some(&"HashSet"),
        );
        assert_eq!(
            bindings_of("let m: std::collections::HashMap<K, V> = init();").get("m"),
            Some(&"HashMap"),
        );
    }

    #[test]
    fn binding_extraction_negative_cases() {
        assert!(bindings_of("use std::collections::HashMap;").is_empty());
        assert!(bindings_of("use std::collections::{HashMap, HashSet};").is_empty());
        assert!(bindings_of("fn f() -> HashMap<K, V> {").is_empty());
        assert!(bindings_of("Heap(BinaryHeap<Entry>),").is_empty());
        assert!(bindings_of("store: Store::Heap(BinaryHeap::new()),").is_empty());
        assert!(bindings_of("if x == HashMap::new() {}").is_empty());
    }

    #[test]
    fn iteration_detection_matches_bound_receivers_only() {
        let calls = iter_calls("self.index.iter() ; plain.iter() ; f().keys()");
        let names: Vec<&str> = calls.iter().map(|(r, _)| r.as_str()).collect();
        assert_eq!(names, ["index", "plain"]);
        assert_eq!(for_loop_receiver("for x in &self.seen {"), Some("seen".to_string()));
        assert_eq!(for_loop_receiver("for x in self.seen.drain() {"), None);
        assert_eq!(for_loop_receiver("for i in 0..n {"), Some("n".to_string()));
        assert_eq!(for_loop_receiver("let x = y;"), None);
    }

    #[test]
    fn waiver_grammar() {
        assert!(parse_waiver("// ordinary comment").is_none());
        let ok = parse_waiver("// detlint:allow(R1, R4) -- commutative u64 sum");
        let (rules, just) = ok.unwrap().unwrap();
        assert_eq!(rules, ["R1", "R4"]);
        assert_eq!(just, "commutative u64 sum");
        assert!(parse_waiver("// detlint:allow(R2)").unwrap().is_err());
        assert!(parse_waiver("// detlint:allow(R9) -- not a rule").unwrap().is_err());
        assert!(parse_waiver("// detlint:allow(R5) -- unwaivable").unwrap().is_err());
        assert!(parse_waiver("// detlint:allow R2 -- no parens").unwrap().is_err());
        assert!(parse_waiver("// detlint:allow(R2) -- short").unwrap().is_err());
    }

    #[test]
    fn token_counting_respects_boundaries() {
        assert_eq!(count_tokens("let t = Instant::now();", "Instant::now"), 1);
        assert_eq!(count_tokens("xInstant::nowy", "Instant::now"), 0);
        assert_eq!(count_tokens("rand::random_range(..)", "rand::random"), 0);
        assert_eq!(count_tokens("a.then(Instant::now)", "Instant::now"), 1);
    }
}
