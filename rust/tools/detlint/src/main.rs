//! detlint CLI: scan source roots, print findings, write the JSON report.
//!
//! Exit codes: 0 — clean (every finding waived); 1 — unwaived findings;
//! 2 — usage or I/O error. The report file is written in both the 0 and 1
//! cases so CI can upload it as an artifact either way.

#![forbid(unsafe_code)]
#![warn(missing_docs)]

use std::path::PathBuf;
use std::process::ExitCode;

const USAGE: &str = "usage: detlint [--report FILE] [--quiet] PATH...\n\
  Scans every .rs file under each PATH against the determinism rulebook\n\
  (DESIGN.md §12) and writes a machine-readable report.\n\
    --report FILE  report path (default: detlint_report.json)\n\
    --quiet, -q    suppress per-finding output; print the summary only\n\
    --help, -h     show this help\n";

fn main() -> ExitCode {
    let mut roots: Vec<PathBuf> = Vec::new();
    let mut report_path = PathBuf::from("detlint_report.json");
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--help" | "-h" => {
                print!("{USAGE}");
                return ExitCode::SUCCESS;
            }
            "--quiet" | "-q" => quiet = true,
            "--report" => {
                let Some(p) = args.next() else {
                    eprintln!("detlint: --report requires a file argument\n{USAGE}");
                    return ExitCode::from(2);
                };
                report_path = PathBuf::from(p);
            }
            other if other.starts_with('-') => {
                eprintln!("detlint: unknown option '{other}'\n{USAGE}");
                return ExitCode::from(2);
            }
            other => roots.push(PathBuf::from(other)),
        }
    }
    if roots.is_empty() {
        eprintln!("detlint: no paths given\n{USAGE}");
        return ExitCode::from(2);
    }

    let report = match detlint::scan_paths(&roots) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("detlint: scan failed: {e}");
            return ExitCode::from(2);
        }
    };

    let json = report.to_json().to_string_pretty() + "\n";
    if let Err(e) = std::fs::write(&report_path, json) {
        eprintln!("detlint: cannot write {}: {e}", report_path.display());
        return ExitCode::from(2);
    }

    if quiet {
        // Summary is the last line of the full rendering.
        let text = report.render_text();
        if let Some(last) = text.lines().last() {
            println!("{last}");
        }
    } else {
        print!("{}", report.render_text());
    }
    println!("detlint: report written to {}", report_path.display());

    if report.clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::FAILURE
    }
}
