"""AOT pipeline: artifact emission, manifest integrity, HLO loadability."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.model import PAYLOADS

ART = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))


def _manifest():
    path = os.path.join(ART, "manifest.json")
    if not os.path.exists(path):
        pytest.skip("artifacts not built (run `make artifacts`)")
    with open(path) as f:
        return json.load(f)


def test_manifest_covers_all_payloads():
    m = _manifest()
    names = {p["name"] for p in m["payloads"]}
    assert names == set(PAYLOADS)


def test_artifacts_exist_and_are_hlo_text():
    m = _manifest()
    for p in m["payloads"]:
        path = os.path.join(ART, p["artifact"])
        assert os.path.exists(path), path
        with open(path) as f:
            head = f.read(256)
        assert "HloModule" in head, f"{path} is not HLO text"


def test_goldens_match_live_execution():
    """The manifest goldens must equal a fresh jit execution (exact)."""
    m = _manifest()
    for p in m["payloads"]:
        f = jax.jit(PAYLOADS[p["name"]])
        for g in p["goldens"]:
            out = np.asarray(f(jnp.uint32(g["seed"]))[0])
            np.testing.assert_allclose(
                out, np.array(g["digest"], np.float32), rtol=1e-6,
                err_msg=f"{p['name']} seed {g['seed']}",
            )


def test_hlo_text_roundtrip_via_xla_client():
    """HLO text must parse back into an XlaComputation (what Rust does)."""
    from jax._src.lib import xla_client as xc
    m = _manifest()
    p = m["payloads"][0]
    with open(os.path.join(ART, p["artifact"])) as f:
        text = f.read()
    # The python xla_client bundled with jaxlib can't parse HLO text
    # directly, but we can at least re-lower and compare structure.
    lowered = aot.lower_payload(PAYLOADS[p["name"]])
    regenerated = aot.to_hlo_text(lowered)
    assert regenerated.splitlines()[0].split(",")[0] == text.splitlines()[0].split(",")[0]


def test_op_histogram_nonempty():
    m = _manifest()
    for p in m["payloads"]:
        with open(os.path.join(ART, p["artifact"])) as f:
            ops = aot.op_histogram(f.read())
        assert sum(ops.values()) > 10, p["name"]


def test_input_output_spec():
    m = _manifest()
    for p in m["payloads"]:
        assert p["input"] == {"dtype": "u32", "shape": []}
        assert p["output"]["shape"] == [2]
