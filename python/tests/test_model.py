"""L2 correctness: payload registry shape/determinism/sensitivity contracts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import PAYLOADS

ALL = sorted(PAYLOADS)


@pytest.mark.parametrize("name", ALL)
def test_output_contract(name):
    out = jax.jit(PAYLOADS[name])(jnp.uint32(42))
    assert isinstance(out, tuple) and len(out) == 1
    v = out[0]
    assert v.shape == (2,) and v.dtype == jnp.float32
    assert np.isfinite(np.asarray(v)).all()


@pytest.mark.parametrize("name", ALL)
def test_deterministic(name):
    f = jax.jit(PAYLOADS[name])
    a = np.asarray(f(jnp.uint32(123))[0])
    b = np.asarray(f(jnp.uint32(123))[0])
    np.testing.assert_array_equal(a, b)


@pytest.mark.parametrize("name", ALL)
def test_seed_sensitivity(name):
    f = jax.jit(PAYLOADS[name])
    a = np.asarray(f(jnp.uint32(1))[0])
    b = np.asarray(f(jnp.uint32(2))[0])
    assert not np.array_equal(a, b), "digest must depend on the seed"


def test_registry_matches_table2():
    # Table II of the paper: the eight FunctionBench applications.
    assert ALL == sorted([
        "chameleon", "dd", "float_operation", "gzip_compression",
        "json_dumps_loads", "linpack", "matmul", "pyaes",
    ])


def test_linpack_converges():
    # The Jacobi iteration must actually reduce the residual: aux output
    # is ||b - A x|| after LINPACK_ITERS sweeps; with d=2 dominance the
    # residual contracts by ~2x per sweep from ||b|| ~ sqrt(n*r/3).
    out = jax.jit(PAYLOADS["linpack"])(jnp.uint32(42))[0]
    resid = float(out[1])
    assert resid < 1.0, f"Jacobi did not converge: residual {resid}"


def test_gzip_ratio_in_range():
    out = jax.jit(PAYLOADS["gzip_compression"])(jnp.uint32(42))[0]
    ratio = float(out[0])
    assert 0.0 <= ratio <= 1.0


def test_json_entropy_in_range():
    out = jax.jit(PAYLOADS["json_dumps_loads"])(jnp.uint32(42))[0]
    entropy = float(out[0])
    assert 0.0 < entropy <= 8.0  # bytes have at most 8 bits of entropy
