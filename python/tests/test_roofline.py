"""The L1 performance model must track the kernels' actual configurations."""

from compile import roofline
from compile import model


def test_all_kernels_covered():
    names = {e.name for e in roofline.all_estimates()}
    assert names == set(model.PAYLOADS), "roofline must cover every payload"


def test_vmem_budgets_respected():
    for e in roofline.all_estimates():
        assert e.vmem_ok, f"{e.name} exceeds VMEM: {e.vmem_bytes}"


def test_mxu_kernels_are_aligned():
    m = roofline.estimate_matmul()
    assert m.tile_efficiency == 1.0, "128-aligned tiles must have full MXU tile efficiency"


def test_unaligned_tiles_penalized():
    bad = roofline.estimate_matmul(bm=130, bn=128, bk=128)
    assert bad.tile_efficiency < 0.6


def test_stream_kernels_bandwidth_bound():
    for name in ["gzip_compression", "chameleon", "dd"]:
        e = roofline.estimate_stream(name)
        assert e.arithmetic_intensity < roofline.RIDGE
        assert e.est_utilization < 0.05, "stream kernels must be BW-capped"


def test_matmul_block_scaling_raises_ai():
    small = roofline.estimate_matmul(bm=128, bn=128, bk=128)
    big = roofline.estimate_matmul(bm=256, bn=256, bk=256)
    assert big.arithmetic_intensity > 1.5 * small.arithmetic_intensity


def test_report_renders():
    text = roofline.report()
    assert "matmul" in text and "est-util" in text
