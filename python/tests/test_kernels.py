"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

Hypothesis sweeps shapes/block sizes/seeds; fixed cases pin the production
configurations used by model.py.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import datagen
from compile import kernels
from compile.kernels import ref

# Hypothesis: moderate case counts — kernels re-trace per shape and this
# image is single-core.
FAST = settings(max_examples=12, deadline=None)


def _f32(shape, seed):
    return datagen.gen_f32(shape, jnp.uint32(seed))


def _u32(n, seed):
    return datagen.gen_u32(n, jnp.uint32(seed))


# ---------------------------------------------------------------- matmul

class TestMatmul:
    def test_production_shape(self):
        x, y = _f32((256, 256), 1), _f32((256, 256), 2)
        np.testing.assert_allclose(
            kernels.matmul(x, y), ref.matmul_ref(x, y), rtol=1e-5, atol=1e-5
        )

    def test_rectangular(self):
        x, y = _f32((128, 256), 3), _f32((256, 64), 4)
        np.testing.assert_allclose(
            kernels.matmul(x, y, bm=64, bn=64, bk=128),
            ref.matmul_ref(x, y),
            rtol=1e-5, atol=1e-5,
        )

    def test_narrow_rhs(self):
        # linpack's 64-column RHS case
        x, y = _f32((256, 256), 5), _f32((256, 64), 6)
        np.testing.assert_allclose(
            kernels.matmul(x, y, bn=64), ref.matmul_ref(x, y),
            rtol=1e-5, atol=1e-5,
        )

    def test_identity(self):
        x = _f32((128, 128), 7)
        eye = jnp.eye(128, dtype=jnp.float32)
        np.testing.assert_allclose(
            kernels.matmul(x, eye, bm=64, bn=64, bk=64), x, rtol=1e-6, atol=1e-6
        )

    def test_block_mismatch_raises(self):
        x, y = _f32((100, 100), 8), _f32((100, 100), 9)
        with pytest.raises(AssertionError):
            kernels.matmul(x, y)

    @FAST
    @given(
        mi=st.integers(1, 3), ni=st.integers(1, 3), ki=st.integers(1, 3),
        seed=st.integers(0, 2**31),
    )
    def test_matmul_property(self, mi, ni, ki, seed):
        bm = bn = bk = 32
        m, n, k = mi * bm, ni * bn, ki * bk
        x, y = _f32((m, k), seed), _f32((k, n), seed + 1)
        np.testing.assert_allclose(
            kernels.matmul(x, y, bm=bm, bn=bn, bk=bk),
            ref.matmul_ref(x, y),
            rtol=1e-4, atol=1e-4,
        )


# ----------------------------------------------------------- float_chain

class TestFloatChain:
    def test_production_shape(self):
        x = _f32((1 << 17,), 10) * 4.0 - 2.0
        np.testing.assert_allclose(
            kernels.float_chain(x), ref.float_chain_ref(x), rtol=1e-5, atol=1e-6
        )

    def test_zero_input(self):
        x = jnp.zeros((8192,), jnp.float32)
        np.testing.assert_allclose(
            kernels.float_chain(x), ref.float_chain_ref(x), rtol=1e-6, atol=1e-7
        )

    @FAST
    @given(
        blocks=st.integers(1, 4), rounds=st.integers(1, 6),
        seed=st.integers(0, 2**31),
    )
    def test_chain_property(self, blocks, rounds, seed):
        n = blocks * 2048
        x = _f32((n,), seed) * 2.0 - 1.0
        np.testing.assert_allclose(
            kernels.float_chain(x, block=2048, rounds=rounds),
            ref.float_chain_ref(x, rounds=rounds),
            rtol=1e-5, atol=1e-6,
        )


# ------------------------------------------------------------ mix_rounds

class TestMixRounds:
    def test_production_shape(self):
        x = _u32(1 << 16, 11)
        np.testing.assert_array_equal(
            kernels.mix_rounds(x), ref.mix_rounds_ref(x)
        )

    def test_bit_exact_single_round(self):
        x = _u32(8192, 12)
        np.testing.assert_array_equal(
            kernels.mix_rounds(x, rounds=1), ref.mix_rounds_ref(x, rounds=1)
        )

    def test_diffusion(self):
        # Flipping one input bit changes ~half the output bits on average.
        x = _u32(8192, 13)
        y1 = np.asarray(kernels.mix_rounds(x))
        y2 = np.asarray(kernels.mix_rounds(x ^ jnp.uint32(1)))
        flipped = np.unpackbits((y1 ^ y2).view(np.uint8)).mean()
        assert 0.4 < flipped < 0.6

    @FAST
    @given(blocks=st.integers(1, 4), rounds=st.integers(1, 8),
           seed=st.integers(0, 2**31))
    def test_mix_property(self, blocks, rounds, seed):
        x = _u32(blocks * 2048, seed)
        np.testing.assert_array_equal(
            kernels.mix_rounds(x, block=2048, rounds=rounds),
            ref.mix_rounds_ref(x, rounds=rounds),
        )


# ------------------------------------------------------------- histogram

class TestHistogram:
    def test_production_shape(self):
        x = datagen.gen_bytes(1 << 16, jnp.uint32(14))
        np.testing.assert_array_equal(kernels.histogram(x), ref.histogram_ref(x))

    def test_counts_sum_to_n(self):
        x = datagen.gen_bytes(1 << 15, jnp.uint32(15))
        assert int(jnp.sum(kernels.histogram(x))) == (1 << 15)

    def test_constant_stream(self):
        x = jnp.full((8192,), 42, jnp.uint32)
        h = np.asarray(kernels.histogram(x))
        assert h[42] == 8192 and h.sum() == 8192

    @FAST
    @given(blocks=st.integers(1, 4), seed=st.integers(0, 2**31))
    def test_histogram_property(self, blocks, seed):
        x = datagen.gen_bytes(blocks * 2048, jnp.uint32(seed))
        np.testing.assert_array_equal(
            kernels.histogram(x, block=2048), ref.histogram_ref(x)
        )


# -------------------------------------------------------- delta_compress

class TestDeltaCompress:
    def test_production_shape(self):
        x = datagen.gen_bytes(1 << 16, jnp.uint32(16))
        np.testing.assert_array_equal(
            kernels.delta_compress(x), ref.delta_compress_ref(x)
        )

    def test_constant_stream_zero_deltas(self):
        x = jnp.full((8192,), 7, jnp.uint32)
        d = np.asarray(kernels.delta_compress(x))
        assert d[0] == 0 and (d == 0).all()

    def test_ramp(self):
        x = jnp.arange(8192, dtype=jnp.uint32) & jnp.uint32(0xFF)
        d = np.asarray(kernels.delta_compress(x))
        # ramp has delta 1 except at the block start and the 255->0 wraps
        assert d[0] == 0
        assert (np.abs(d[1:]) <= 255).all()

    @FAST
    @given(blocks=st.integers(1, 4), seed=st.integers(0, 2**31))
    def test_delta_property(self, blocks, seed):
        x = datagen.gen_bytes(blocks * 2048, jnp.uint32(seed))
        np.testing.assert_array_equal(
            kernels.delta_compress(x, block=2048),
            ref.delta_compress_ref(x, block=2048),
        )


# -------------------------------------------------------- gather_permute

class TestGatherPermute:
    def test_production_shape(self):
        x = _u32(1 << 16, 17)
        np.testing.assert_array_equal(
            kernels.gather_permute(x), ref.gather_permute_ref(x)
        )

    def test_values_from_input(self):
        x = _u32(8192, 18)
        y = np.asarray(kernels.gather_permute(x))
        assert set(y.tolist()) <= set(np.asarray(x).tolist())

    @FAST
    @given(blocks=st.integers(1, 4), seed=st.integers(0, 2**31))
    def test_gather_property(self, blocks, seed):
        x = _u32(blocks * 2048, seed)
        np.testing.assert_array_equal(
            kernels.gather_permute(x, block=2048),
            ref.gather_permute_ref(x, block=2048),
        )


# ------------------------------------------------------ strided_checksum

class TestStridedChecksum:
    def test_production_shape(self):
        x = _u32(1 << 16, 19)
        np.testing.assert_array_equal(
            kernels.strided_checksum(x), ref.strided_checksum_ref(x)
        )

    def test_zero_stream(self):
        x = jnp.zeros((8192,), jnp.uint32)
        assert int(kernels.strided_checksum(x)[0]) == 0

    def test_linearity_mod_2_32(self):
        x = _u32(8192, 20)
        c1 = int(kernels.strided_checksum(x)[0])
        c2 = int(kernels.strided_checksum(x * jnp.uint32(2))[0])
        assert c2 == (2 * c1) % (1 << 32)

    @FAST
    @given(blocks=st.integers(1, 4), seed=st.integers(0, 2**31))
    def test_checksum_property(self, blocks, seed):
        x = _u32(blocks * 2048, seed)
        np.testing.assert_array_equal(
            kernels.strided_checksum(x, block=2048),
            ref.strided_checksum_ref(x, block=2048),
        )
