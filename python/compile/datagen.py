"""Deterministic on-device data generation shared by payloads and oracles.

Serverless function payloads take a single u32 seed as input and synthesize
their working set on device from that seed. This keeps the Rust->PJRT
marshalling trivial (one scalar in, one small vector out) while still
exercising real compute: the generator is a SplitMix32-style integer mixer
evaluated over an iota, which XLA fuses into the consumer kernel.

The same helpers back `kernels/ref.py`, so the pure-jnp oracle and the Pallas
kernels consume bit-identical inputs.
"""

import jax.numpy as jnp

# SplitMix64's golden-ratio increment, truncated to 32 bits.
GOLDEN32 = jnp.uint32(0x9E3779B9)


def mix32(x):
    """SplitMix32 finalizer: a high-quality 32-bit integer mixer.

    Operates on uint32 arrays with wrapping arithmetic (XLA semantics).
    """
    x = jnp.asarray(x, jnp.uint32)
    x = (x ^ (x >> jnp.uint32(16))) * jnp.uint32(0x7FEB352D)
    x = (x ^ (x >> jnp.uint32(15))) * jnp.uint32(0x846CA68B)
    x = x ^ (x >> jnp.uint32(16))
    return x


def gen_u32(n, seed):
    """n pseudo-random uint32s derived from `seed` (scalar or 0-d array)."""
    seed = jnp.asarray(seed, jnp.uint32)
    i = jnp.arange(n, dtype=jnp.uint32)
    return mix32(i + seed * GOLDEN32 + jnp.uint32(1))


def gen_f32(shape, seed):
    """Uniform [0, 1) float32s of `shape` derived from `seed`."""
    n = 1
    for d in shape:
        n *= d
    u = gen_u32(n, seed)
    # 24-bit mantissa path: exact uniform grid in [0, 1).
    f = (u >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(1.0 / (1 << 24))
    return f.reshape(shape)


def gen_bytes(n, seed):
    """n pseudo-random byte values (as uint32 in [0, 256))."""
    return gen_u32(n, seed) & jnp.uint32(0xFF)
