"""L1 performance model: VMEM footprint + MXU/VPU utilization estimates.

Pallas runs interpret=True on this image (CPU PJRT cannot execute Mosaic
custom-calls), so real-TPU performance cannot be *measured* here; per the
project brief it is *estimated* from the kernels' block shapes. This module
is the single source of truth for those estimates (DESIGN.md §Perf /
EXPERIMENTS.md §Perf) and is unit-tested so the numbers track the kernels.

Model (TPU v4-ish constants, documented not measured):
- VMEM ~= 16 MiB/core. A kernel's working set per grid step must fit.
- MXU: 128x128 systolic array; matmul efficiency ~= how well (bm, bn, bk)
  tile to multiples of 128 x how much of the step is matmul work.
- VPU: 8x128 lanes; elementwise efficiency ~= lane alignment of the block.
- HBM BW ~= 1.2 TB/s; arithmetic intensity (flops/byte) below the ridge
  point means the kernel is bandwidth-bound and utilization is capped by
  AI / ridge.
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024
MXU_DIM = 128
VPU_LANES = 128
PEAK_FLOPS = 275e12  # bf16 MXU peak, f32 ~1/2 — we report relative ratios
HBM_BW = 1.2e12
RIDGE = PEAK_FLOPS / HBM_BW  # flops/byte needed to be compute-bound


@dataclass
class KernelEstimate:
    name: str
    block_desc: str
    vmem_bytes: int
    flops_per_step: float
    bytes_per_step: float
    unit: str  # "MXU" or "VPU"

    @property
    def vmem_ok(self) -> bool:
        return self.vmem_bytes <= VMEM_BYTES

    @property
    def arithmetic_intensity(self) -> float:
        return self.flops_per_step / max(self.bytes_per_step, 1.0)

    @property
    def tile_efficiency(self) -> float:
        """How well the block maps to the execution unit (static)."""
        return self._tile_eff

    _tile_eff: float = 1.0

    @property
    def est_utilization(self) -> float:
        """Min of tile efficiency and the bandwidth cap."""
        bw_cap = min(1.0, self.arithmetic_intensity / RIDGE)
        return min(self._tile_eff, bw_cap)


def _mxu_tile_eff(bm: int, bn: int, bk: int) -> float:
    """Fraction of MXU cycles doing useful work for a (bm,bn,bk) tile."""
    def frac(d):
        full = d // MXU_DIM
        rem = d % MXU_DIM
        used = full * MXU_DIM + rem
        padded = (full + (1 if rem else 0)) * MXU_DIM
        return used / max(padded, 1)
    return frac(bm) * frac(bn) * frac(bk)


def _vpu_tile_eff(block: int) -> float:
    rem = block % VPU_LANES
    if rem == 0:
        return 1.0
    rows = block // VPU_LANES + 1
    return block / (rows * VPU_LANES)


def estimate_matmul(m=512, n=512, k=512, bm=256, bn=256, bk=512) -> KernelEstimate:
    vmem = 4 * (bm * bk + bk * bn + bm * bn)
    e = KernelEstimate(
        name="matmul",
        block_desc=f"({bm},{bk})x({bk},{bn})->({bm},{bn}), grid ({m//bm},{n//bn},{k//bk})",
        vmem_bytes=vmem,
        flops_per_step=2.0 * bm * bn * bk,
        bytes_per_step=4.0 * (bm * bk + bk * bn + bm * bn / (k // bk)),
        unit="MXU",
    )
    e._tile_eff = _mxu_tile_eff(bm, bn, bk)
    return e


def estimate_linpack(n=512, r=128, bm=128, bn=128, bk=128) -> KernelEstimate:
    vmem = 4 * (bm * bk + bk * bn + bm * bn)
    e = KernelEstimate(
        name="linpack",
        block_desc=f"jacobi matvec blocks ({bm},{bk})x({bk},{bn}), grid ({n//bm},{r//bn},{n//bk})",
        vmem_bytes=vmem,
        flops_per_step=2.0 * bm * bn * bk,
        bytes_per_step=4.0 * (bm * bk + bk * bn),
        unit="MXU",
    )
    e._tile_eff = _mxu_tile_eff(bm, bn, bk)
    return e


def estimate_elementwise(block=8192, rounds=4, flops_per_elem_round=12) -> KernelEstimate:
    e = KernelEstimate(
        name="float_operation",
        block_desc=f"1-D block {block}, {rounds} fused transcendental rounds",
        vmem_bytes=4 * block * 2,
        flops_per_step=float(block * rounds * flops_per_elem_round),
        bytes_per_step=8.0 * block,  # one read + one write
        unit="VPU",
    )
    e._tile_eff = _vpu_tile_eff(block)
    return e


def estimate_mix(block=8192, rounds=24, ops_per_elem_round=8) -> KernelEstimate:
    e = KernelEstimate(
        name="pyaes",
        block_desc=f"1-D u32 block {block}, {rounds} ARX rounds in VMEM",
        vmem_bytes=4 * block * 2,
        flops_per_step=float(block * rounds * ops_per_elem_round),
        bytes_per_step=8.0 * block,
        unit="VPU",
    )
    e._tile_eff = _vpu_tile_eff(block)
    return e


def estimate_histogram(block=8192, bins=256) -> KernelEstimate:
    e = KernelEstimate(
        name="json_dumps_loads",
        block_desc=f"compare-reduce {bins}x{block} per step",
        vmem_bytes=4 * (block + bins) + block * bins // 8,
        flops_per_step=float(block * bins),
        bytes_per_step=4.0 * (block + bins),
        unit="VPU",
    )
    e._tile_eff = _vpu_tile_eff(block)
    return e


def estimate_stream(name: str, block=8192, ops_per_elem=2) -> KernelEstimate:
    e = KernelEstimate(
        name=name,
        block_desc=f"1-D block {block}, {ops_per_elem} ops/elem (memory-bound)",
        vmem_bytes=4 * block * 2,
        flops_per_step=float(block * ops_per_elem),
        bytes_per_step=8.0 * block,
        unit="VPU",
    )
    e._tile_eff = _vpu_tile_eff(block)
    return e


def all_estimates():
    return [
        estimate_matmul(),
        estimate_linpack(),
        estimate_elementwise(),
        estimate_mix(),
        estimate_histogram(),
        estimate_stream("gzip_compression", ops_per_elem=4),
        estimate_stream("chameleon", ops_per_elem=6),
        estimate_stream("dd", ops_per_elem=3),
    ]


def report() -> str:
    lines = [
        "# L1 Pallas kernel roofline estimates (TPU-v4-class constants)",
        f"(VMEM 16 MiB, MXU 128x128, ridge {RIDGE:.0f} flops/byte)",
        "",
        f"{'kernel':<18} {'unit':<4} {'VMEM/step':>10} {'AI':>8} {'tile-eff':>9} {'est-util':>9}  block",
    ]
    for e in all_estimates():
        lines.append(
            f"{e.name:<18} {e.unit:<4} {e.vmem_bytes/1024:>8.0f}KB "
            f"{e.arithmetic_intensity:>8.1f} {e.tile_efficiency:>9.2f} "
            f"{e.est_utilization:>9.2f}  {e.block_desc}"
        )
    lines.append("")
    lines.append(
        "Matmul/linpack are MXU-bound with 128-aligned tiles (tile-eff 1.0);\n"
        "the byte-stream kernels are bandwidth-bound by design (AI << ridge),\n"
        "matching their FunctionBench roles (disk/network-flavoured work)."
    )
    return "\n".join(lines)


if __name__ == "__main__":
    print(report())
