"""AOT compiler: lower every payload to HLO text + manifest for the Rust side.

Interchange format is HLO *text*, not a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version behind the Rust `xla` crate) rejects; the text parser reassigns ids
and round-trips cleanly. See /opt/xla-example/README.md.

Outputs (under --out-dir, default ../artifacts):
  <name>.hlo.txt   one per payload in model.PAYLOADS
  manifest.json    input/output specs + golden digests for Rust-side
                   numeric verification (seed 42 and 7)

`--report` additionally prints per-payload HLO op counts (fusion sanity:
L2 perf target is "no redundant recompute, one fused module per payload").
"""

import argparse
import collections
import json
import os
import re
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import PAYLOADS

GOLDEN_SEEDS = (42, 7)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_payload(fn):
    spec = jax.ShapeDtypeStruct((), jnp.uint32)
    return jax.jit(fn).lower(spec)


def op_histogram(hlo_text: str):
    """Rough opcode histogram from HLO text (perf report)."""
    ops = collections.Counter()
    for line in hlo_text.splitlines():
        m = re.search(r"=\s+\S+\s+([a-z0-9-]+)\(", line)
        if m:
            ops[m.group(1)] += 1
    return ops


def build(out_dir: str, report: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"format": "hlo-text", "payloads": []}
    for name, fn in PAYLOADS.items():
        lowered = lower_payload(fn)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        goldens = []
        for seed in GOLDEN_SEEDS:
            out = jax.jit(fn)(jnp.uint32(seed))[0]
            goldens.append(
                {"seed": seed, "digest": [float(out[0]), float(out[1])]}
            )
        entry = {
            "name": name,
            "artifact": f"{name}.hlo.txt",
            "input": {"dtype": "u32", "shape": []},
            "output": {"dtype": "f32", "shape": [2], "tuple": True},
            "goldens": goldens,
            "hlo_bytes": len(text),
        }
        manifest["payloads"].append(entry)
        if report:
            ops = op_histogram(text)
            total = sum(ops.values())
            top = ", ".join(f"{k}:{v}" for k, v in ops.most_common(6))
            print(f"  {name:>20}: {total:5d} ops ({top})")
        print(f"wrote {path} ({len(text)} bytes)", file=sys.stderr)
    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {mpath}", file=sys.stderr)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--report", action="store_true", help="print HLO op histograms")
    args = ap.parse_args()
    build(os.path.abspath(args.out_dir), report=args.report)


if __name__ == "__main__":
    main()
