"""Layer-1 Pallas kernels (interpret=True) for the FunctionBench payloads."""

from .matmul import matmul
from .elementwise import float_chain
from .mix import mix_rounds
from .bytes_ops import histogram, delta_compress, gather_permute, strided_checksum

__all__ = [
    "matmul",
    "float_chain",
    "mix_rounds",
    "histogram",
    "delta_compress",
    "gather_permute",
    "strided_checksum",
]
