"""ARX mixing rounds — hot-spot of the `pyaes` payload.

FunctionBench's pyaes runs many cheap rounds of byte-level substitution and
permutation over a block. Table-based AES S-boxes are gather-heavy and map
poorly to vector units, so the TPU rethink keeps the *structure* — many
sequential rounds of diffusion over a wide state — using an ARX
(add-rotate-xor) network over u32 lanes, which vectorizes cleanly on the VPU.

Each grid step owns one VMEM-resident state block and runs all rounds locally
(round loop inside the kernel), so HBM traffic is paid once per block rather
than once per round — the same trick a CUDA AES kernel plays with shared
memory residency.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rotl(x, r):
    """Rotate-left each u32 lane by constant r."""
    r = jnp.uint32(r)
    return (x << r) | (x >> (jnp.uint32(32) - r))


def _mix_kernel(x_ref, o_ref, *, rounds):
    s = x_ref[...]
    for rnd in range(rounds):
        # Round constant keyed by round index (odd => invertible multiply).
        rc = jnp.uint32(0x9E3779B9) * jnp.uint32(2 * rnd + 1)
        s = s + rc
        s = s ^ _rotl(s, 13)
        s = s * jnp.uint32(0x85EBCA6B) | jnp.uint32(1)
        s = s ^ _rotl(s, 17)
    o_ref[...] = s


@functools.partial(jax.jit, static_argnames=("block", "rounds"))
def mix_rounds(x, *, block=8192, rounds=16):
    """Run `rounds` of ARX diffusion over a 1-D u32 state vector."""
    (n,) = x.shape
    assert n % block == 0, f"block {block} must divide length {n}"
    return pl.pallas_call(
        functools.partial(_mix_kernel, rounds=rounds),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=True,
    )(x)
