"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: `python/tests/test_kernels.py` asserts
allclose / array_equal between each kernel and its oracle over hypothesis-
generated shapes, dtypes and seeds. Keep these boring — no Pallas, no grids,
just the mathematical definition.
"""

import jax.numpy as jnp

from ..datagen import mix32


def matmul_ref(x, y):
    """Oracle for kernels.matmul.matmul."""
    return jnp.dot(x, y, preferred_element_type=jnp.float32)


def float_chain_ref(x, *, rounds=4):
    """Oracle for kernels.elementwise.float_chain."""
    y = x
    for _ in range(rounds):
        y = jnp.sin(y) * jnp.exp(-y * y) + jnp.sqrt(jnp.abs(y) + 1e-6)
        y = y * jnp.float32(0.5)
    return y


def _rotl(x, r):
    r = jnp.uint32(r)
    return (x << r) | (x >> (jnp.uint32(32) - r))


def mix_rounds_ref(x, *, rounds=16):
    """Oracle for kernels.mix.mix_rounds."""
    s = jnp.asarray(x, jnp.uint32)
    for rnd in range(rounds):
        rc = jnp.uint32(0x9E3779B9) * jnp.uint32(2 * rnd + 1)
        s = s + rc
        s = s ^ _rotl(s, 13)
        s = s * jnp.uint32(0x85EBCA6B) | jnp.uint32(1)
        s = s ^ _rotl(s, 17)
    return s


def histogram_ref(x):
    """Oracle for kernels.bytes_ops.histogram."""
    bins = jnp.arange(256, dtype=jnp.uint32)
    return jnp.sum((x[None, :] == bins[:, None]).astype(jnp.uint32), axis=1)


def delta_compress_ref(x, *, block=8192):
    """Oracle for kernels.bytes_ops.delta_compress (block-local deltas)."""
    xi = x.astype(jnp.int32).reshape(-1, block)
    prev = jnp.concatenate([xi[:, :1], xi[:, :-1]], axis=1)
    return (xi - prev).reshape(-1)


def gather_permute_ref(x, *, block=8192):
    """Oracle for kernels.bytes_ops.gather_permute (block-local gathers)."""
    xb = x.reshape(-1, block)
    idx = jnp.arange(block, dtype=jnp.uint32)
    out = []
    for b in range(xb.shape[0]):
        perm = mix32(idx + jnp.uint32(b + 1)) % jnp.uint32(block)
        out.append(xb[b][perm])
    return jnp.stack(out).reshape(-1)


def strided_checksum_ref(x, *, block=8192):
    """Oracle for kernels.bytes_ops.strided_checksum."""
    n = x.shape[0]
    i = jnp.arange(n, dtype=jnp.uint32) % jnp.uint32(block)
    w = (i & jnp.uint32(0xFF)) + jnp.uint32(1)
    return jnp.sum(x * w, keepdims=True)
