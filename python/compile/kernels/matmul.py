"""Blocked Pallas matmul — the compute hot-spot of `matmul` and `linpack`.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid walks (M/bm, N/bn,
K/bk); each step loads one (bm, bk) tile of A and one (bk, bn) tile of B into
VMEM and feeds the MXU-shaped `jnp.dot`. The output block is revisited along
the K dimension and accumulated in place — the BlockSpec index map for the
output ignores `k`, which expresses the HBM<->VMEM reuse schedule that a CUDA
version would express with threadblock tiling over shared memory.

interpret=True is mandatory on this image: CPU PJRT cannot execute Mosaic
custom-calls, and interpret mode lowers the kernel to plain HLO so the AOT
artifact runs anywhere.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(x_ref, y_ref, o_ref, *, k_steps):
    """One grid step: accumulate x_block @ y_block into the output block."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul(x, y, *, bm=128, bn=128, bk=128):
    """Blocked matmul `x @ y` via Pallas.

    Shapes must tile evenly: x (M, K), y (K, N) with bm | M, bn | N, bk | K.
    Defaults (128, 128, 128) are MXU-aligned tiles; VMEM footprint per step is
    bm*bk + bk*bn + bm*bn floats = 192 KiB at the defaults.
    """
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"block sizes ({bm},{bn},{bk}) must divide shapes ({m},{n},{k})"
    )
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, y)
