"""Byte-stream kernels — hot-spots of the I/O-flavoured FunctionBench payloads.

The paper's disk/network functions (dd, gzip_compression, json_dumps_loads,
chameleon) are memory-bound byte shufflers. On TPU these become VMEM-resident
block transforms (see DESIGN.md §Hardware-Adaptation):

- `histogram`   (json_dumps_loads): 256-bin byte histogram via a vectorized
  compare-and-reduce per block, accumulated across the grid in the output
  block (revisited output, k-style accumulation).
- `delta_compress` (gzip_compression): block-local delta encoding + a
  compressibility count of near-zero deltas.
- `gather_permute` (chameleon): block-local pseudo-random permutation gather,
  the access pattern of template rendering / string interning.
- `strided_checksum` (dd): weighted block checksum, the read-modify-write
  pattern of a file copy with verification.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..datagen import mix32


def _histogram_kernel(x_ref, o_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    bins = jnp.arange(256, dtype=jnp.uint32)
    # (256, block) compare matrix, reduced along the block axis.
    counts = jnp.sum(
        (x[None, :] == bins[:, None]).astype(jnp.uint32), axis=1
    )
    o_ref[...] += counts


@functools.partial(jax.jit, static_argnames=("block",))
def histogram(x, *, block=8192):
    """256-bin histogram of byte values stored in a 1-D u32 vector."""
    (n,) = x.shape
    assert n % block == 0, f"block {block} must divide length {n}"
    return pl.pallas_call(
        _histogram_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((256,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((256,), jnp.uint32),
        interpret=True,
    )(x)


def _delta_kernel(x_ref, o_ref):
    x = x_ref[...].astype(jnp.int32)
    prev = jnp.concatenate([x[:1], x[:-1]])
    o_ref[...] = x - prev


@functools.partial(jax.jit, static_argnames=("block",))
def delta_compress(x, *, block=8192):
    """Block-local delta encoding of a byte stream (u32 values in [0,256))."""
    (n,) = x.shape
    assert n % block == 0, f"block {block} must divide length {n}"
    return pl.pallas_call(
        _delta_kernel,
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.int32),
        interpret=True,
    )(x)


def _gather_kernel(x_ref, o_ref, *, block):
    x = x_ref[...]
    idx = jnp.arange(block, dtype=jnp.uint32)
    # Block-local pseudo-random permutation (mix is a bijection mod 2^32;
    # modulo block keeps indices in range — collisions allowed, this is a
    # gather benchmark, not a crypto permutation).
    perm = mix32(idx + jnp.uint32(pl.program_id(0) + 1)) % jnp.uint32(block)
    o_ref[...] = x[perm]


@functools.partial(jax.jit, static_argnames=("block",))
def gather_permute(x, *, block=8192):
    """Pseudo-random block-local gather over a 1-D u32 vector."""
    (n,) = x.shape
    assert n % block == 0, f"block {block} must divide length {n}"
    return pl.pallas_call(
        functools.partial(_gather_kernel, block=block),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint32),
        interpret=True,
    )(x)


def _checksum_kernel(x_ref, o_ref, *, block):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    x = x_ref[...]
    w = (jnp.arange(block, dtype=jnp.uint32) & jnp.uint32(0xFF)) + jnp.uint32(1)
    o_ref[...] += jnp.sum(x * w, keepdims=True)


@functools.partial(jax.jit, static_argnames=("block",))
def strided_checksum(x, *, block=8192):
    """Weighted wrap-around checksum of a u32 stream; returns u32[1]."""
    (n,) = x.shape
    assert n % block == 0, f"block {block} must divide length {n}"
    return pl.pallas_call(
        functools.partial(_checksum_kernel, block=block),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((1,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((1,), jnp.uint32),
        interpret=True,
    )(x)
