"""Elementwise transcendental chain — hot-spot of `float_operation`.

FunctionBench's float_operation benchmarks sqrt/sin/exp style scalar math in a
tight loop. The TPU rethink: a VPU-friendly elementwise pipeline over
lane-aligned blocks. The grid walks the vector in `block` chunks; each chunk
is one HBM->VMEM->HBM pass with the whole chain fused in registers, so the
kernel is bandwidth-bound with arithmetic intensity ~= chain length.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _chain_kernel(x_ref, o_ref, *, rounds):
    x = x_ref[...]
    y = x
    # `rounds` fused transcendental passes; matches ref.float_chain_ref.
    for _ in range(rounds):
        y = jnp.sin(y) * jnp.exp(-y * y) + jnp.sqrt(jnp.abs(y) + 1e-6)
        y = y * jnp.float32(0.5)
    o_ref[...] = y


@functools.partial(jax.jit, static_argnames=("block", "rounds"))
def float_chain(x, *, block=8192, rounds=4):
    """Apply `rounds` of the transcendental chain to a 1-D f32 vector."""
    (n,) = x.shape
    assert n % block == 0, f"block {block} must divide length {n}"
    return pl.pallas_call(
        functools.partial(_chain_kernel, rounds=rounds),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)
