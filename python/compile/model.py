"""Layer-2: the serverless function payloads as JAX compute graphs.

Each FunctionBench-inspired payload is a function `seed_u32 -> f32[2]`
(digest + auxiliary statistic). The working set is synthesized on device from
the seed (see datagen.py), so the Rust coordinator marshals exactly one scalar
in and one tiny vector out per invocation. Every payload routes its compute
hot-spot through a Pallas kernel (kernels/*), so the AOT artifact contains the
kernel lowering, and Python never runs at serving time.

`PAYLOADS` is the registry the AOT compiler (aot.py) walks; the Rust workload
module mirrors the same eight names (x5 copies = 40 functions, Table II).
"""

import jax.numpy as jnp

from . import datagen
from . import kernels

# Problem sizes: picked so a warm invocation lands in the low-millisecond
# range on a single CPU core (paper's warm starts: 58-549 ms on Python;
# orderings preserved, absolute scale is faster because the payloads are
# compiled XLA rather than interpreted Python).
MATMUL_N = 512
LINPACK_N = 512
LINPACK_RHS = 128
LINPACK_ITERS = 4
VEC_N = 1 << 19        # float_operation vector length
STREAM_N = 1 << 19     # byte-stream payload length
AES_ROUNDS = 24
CHAIN_ROUNDS = 4


def _digest_pair(a, b):
    """Pack two scalars into the f32[2] payload output."""
    return (jnp.stack([a.astype(jnp.float32), b.astype(jnp.float32)]),)


def payload_matmul(seed):
    """`matmul`: dense C = A @ B on synthesized operands.

    Block shapes from the §Perf roofline iteration: (256, 256, 512) tiles
    raise the arithmetic intensity from 28 to 52 flops/byte vs the naive
    128-cube (VMEM 1.3 MiB/step, still MXU-aligned) — see
    compile/roofline.py and EXPERIMENTS.md §Perf.
    """
    a = datagen.gen_f32((MATMUL_N, MATMUL_N), seed)
    b = datagen.gen_f32((MATMUL_N, MATMUL_N), seed + jnp.uint32(1))
    c = kernels.matmul(a, b, bm=256, bn=256, bk=512)
    return _digest_pair(jnp.mean(c), jnp.trace(c))


def payload_linpack(seed):
    """`linpack`: Jacobi iterations on a diagonally dominant system.

    x_{t+1} = (B - (A - D) x_t) / d with A strictly diagonally dominant;
    the A @ x_t hot-spot goes through the Pallas matmul (8 stacked RHS so
    the MXU tile is not degenerate).
    """
    n, r = LINPACK_N, LINPACK_RHS
    a = datagen.gen_f32((n, n), seed) * jnp.float32(1.0 / n)
    d = jnp.float32(2.0)  # dominant diagonal
    a = a - jnp.diag(jnp.diag(a)) + d * jnp.eye(n, dtype=jnp.float32)
    b = datagen.gen_f32((n, r), seed + jnp.uint32(7))
    x = jnp.zeros((n, r), jnp.float32)
    for _ in range(LINPACK_ITERS):
        ax = kernels.matmul(a, x, bn=r)  # bn=128: full MXU tile (§Perf)
        x = x + (b - ax) / d
    resid = b - kernels.matmul(a, x, bn=r)
    return _digest_pair(jnp.mean(x), jnp.sqrt(jnp.sum(resid * resid)))


def payload_float_operation(seed):
    """`float_operation`: transcendental chain over a long vector."""
    x = datagen.gen_f32((VEC_N,), seed) * jnp.float32(4.0) - jnp.float32(2.0)
    y = kernels.float_chain(x, rounds=CHAIN_ROUNDS)
    return _digest_pair(jnp.sum(y), jnp.max(y))


def payload_pyaes(seed):
    """`pyaes`: ARX diffusion rounds over a wide u32 state."""
    s = datagen.gen_u32(STREAM_N, seed)
    out = kernels.mix_rounds(s, rounds=AES_ROUNDS)
    lo = (out & jnp.uint32(0xFFFF)).astype(jnp.float32)
    return _digest_pair(jnp.mean(lo), jnp.max(lo))


def payload_json_dumps_loads(seed):
    """`json_dumps_loads`: byte histogram + entropy estimate."""
    x = datagen.gen_bytes(STREAM_N, seed)
    h = kernels.histogram(x)
    p = h.astype(jnp.float32) / jnp.float32(STREAM_N)
    entropy = -jnp.sum(p * jnp.log2(p + jnp.float32(1e-12)))
    return _digest_pair(entropy, jnp.max(h).astype(jnp.float32))


def payload_gzip_compression(seed):
    """`gzip_compression`: delta encoding + compressibility estimate."""
    x = datagen.gen_bytes(STREAM_N, seed)
    # Make the stream locally correlated so deltas are small-ish.
    x = (x >> jnp.uint32(3)) + (jnp.arange(STREAM_N, dtype=jnp.uint32) >> jnp.uint32(8)) & jnp.uint32(0xFF)
    d = kernels.delta_compress(x)
    small = jnp.sum((jnp.abs(d) < 4).astype(jnp.float32))
    ratio = small / jnp.float32(STREAM_N)
    return _digest_pair(ratio, jnp.sum(jnp.abs(d)).astype(jnp.float32))


def payload_chameleon(seed):
    """`chameleon`: permutation gathers (template-rendering access pattern)."""
    x = datagen.gen_u32(STREAM_N, seed)
    y = kernels.gather_permute(x)
    y = kernels.gather_permute(y)
    lo = (y & jnp.uint32(0xFFFF)).astype(jnp.float32)
    return _digest_pair(jnp.mean(lo), jnp.min(lo))


def payload_dd(seed):
    """`dd`: bulk copy + weighted checksum (file I/O access pattern)."""
    x = datagen.gen_u32(STREAM_N, seed)
    c = kernels.strided_checksum(x)
    c2 = kernels.strided_checksum(x ^ jnp.uint32(0xA5A5A5A5))
    return _digest_pair(
        (c[0] & jnp.uint32(0xFFFFFF)).astype(jnp.float32),
        (c2[0] & jnp.uint32(0xFFFFFF)).astype(jnp.float32),
    )


# Registry: name -> payload. Order matches Table II of the paper.
PAYLOADS = {
    "chameleon": payload_chameleon,
    "float_operation": payload_float_operation,
    "linpack": payload_linpack,
    "matmul": payload_matmul,
    "pyaes": payload_pyaes,
    "dd": payload_dd,
    "gzip_compression": payload_gzip_compression,
    "json_dumps_loads": payload_json_dumps_loads,
}
